"""CPU R-tree baseline (paper §7.3, following [11]).

The paper's CPU comparison point stores ``r`` consecutive trajectory
segments per minimum bounding box (MBB, 4-D: x/y/z/t), indexes the MBBs in
an in-memory R-tree, and answers a distance-threshold query with
search-and-refine: the search phase walks the tree collecting leaf MBBs
that intersect the query segment's d-expanded MBB; the refine phase runs
the exact interaction computation on the candidate segments.

Implementation notes:

* Trajectory splitting: each trajectory's segments are chunked ``r`` at a
  time into one MBB (the paper's [11] strategy with a fixed per-MBB segment
  count; r=12 was best on GALAXY, Fig. 5).
* The tree is STR bulk-loaded (sort-tile-recursive) with fanout 16 — the
  standard static construction for in-memory R-trees.
* The refine phase reuses the same interaction math as the device path
  (``repro.kernels.ref``) on the candidate set, so the CPU baseline and the
  accelerated engine return bit-identical intervals.
* ``query_parallel`` dispatches independent query segments across a thread
  pool (the paper's OpenMP analogue; numpy releases the GIL in the refine
  kernels).

Public entry point: ``repro.api.TrajectoryDB.query(..., backend="rtree")``
(``ExecutionPolicy.rtree_r/rtree_fanout/rtree_threads`` carry the knobs).
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core.engine import ResultSet
from repro.core.segments import SegmentArray
from repro.kernels import ops


@dataclasses.dataclass
class _Level:
    lo: np.ndarray     # (n, 4) mins  (x, y, z, t)
    hi: np.ndarray     # (n, 4) maxs
    child: np.ndarray  # (n,) index of first child in level below
    count: np.ndarray  # (n,) number of children


class RTree:
    """STR bulk-loaded R-tree over per-trajectory segment MBBs."""

    def __init__(self, db: SegmentArray, r: int = 12, fanout: int = 16):
        self.db = db
        self.r = r
        self.fanout = fanout
        self._build_leaves()
        self._build_tree()

    # -- leaves: r consecutive same-trajectory segments per MBB ----------
    def _build_leaves(self) -> None:
        db = self.db
        order = np.lexsort((db.seg_id, db.traj_id))
        self.seg_order = order                    # leaf-contiguous segment order
        tid = db.traj_id[order]
        xs, ys, zs = db.xs[order], db.ys[order], db.zs[order]
        xe, ye, ze = db.xe[order], db.ye[order], db.ze[order]
        ts, te = db.ts[order], db.te[order]
        lo_pt = np.stack([np.minimum(xs, xe), np.minimum(ys, ye),
                          np.minimum(zs, ze), ts], axis=1)
        hi_pt = np.stack([np.maximum(xs, xe), np.maximum(ys, ye),
                          np.maximum(zs, ze), te], axis=1)
        # Chunk boundaries: every r segments, restarting at trajectory breaks.
        n = len(db)
        breaks = np.nonzero(np.diff(tid))[0] + 1
        starts = [0]
        prev = 0
        bset = set(breaks.tolist())
        for i in range(1, n):
            if i in bset or i - prev >= self.r:
                starts.append(i)
                prev = i
        starts = np.asarray(starts, dtype=np.int64)
        ends = np.append(starts[1:], n)
        self.leaf_first = starts
        self.leaf_count = ends - starts
        self.leaf_lo = np.minimum.reduceat(lo_pt, starts, axis=0)
        self.leaf_hi = np.maximum.reduceat(hi_pt, starts, axis=0)

    # -- STR bulk load ----------------------------------------------------
    def _build_tree(self) -> None:
        lo, hi = self.leaf_lo, self.leaf_hi
        idx = np.arange(lo.shape[0], dtype=np.int64)
        # STR ordering: sort by x-center then tile by t-center.
        cx = (lo[:, 0] + hi[:, 0]) / 2
        ct = (lo[:, 3] + hi[:, 3]) / 2
        order = np.lexsort((cx, ct))
        self.leaf_perm = idx[order]
        self.levels: list[_Level] = []
        cur_lo, cur_hi = lo[order], hi[order]
        child = self.leaf_perm.copy()
        is_leaf_level = True
        while cur_lo.shape[0] > 1:
            n = cur_lo.shape[0]
            f = self.fanout
            starts = np.arange(0, n, f, dtype=np.int64)
            ends = np.minimum(starts + f, n)
            lvl = _Level(
                lo=np.minimum.reduceat(cur_lo, starts, axis=0),
                hi=np.maximum.reduceat(cur_hi, starts, axis=0),
                child=starts, count=ends - starts)
            if is_leaf_level:
                self.leaf_level_children = child
                is_leaf_level = False
            self.levels.append(lvl)
            cur_lo, cur_hi = lvl.lo, lvl.hi
        if is_leaf_level:                           # single-leaf tree
            self.leaf_level_children = child
            self.levels.append(_Level(
                lo=cur_lo, hi=cur_hi,
                child=np.zeros(1, np.int64), count=np.ones(1, np.int64)))

    # -- search -----------------------------------------------------------
    def _search_leaves(self, qlo: np.ndarray, qhi: np.ndarray) -> np.ndarray:
        """Leaf ids whose MBB intersects [qlo, qhi] (pointer-chasing walk)."""
        hits: list[int] = []
        top = len(self.levels) - 1
        stack = [(top, i) for i in range(self.levels[top].lo.shape[0])]
        while stack:
            lvl_i, node = stack.pop()
            lvl = self.levels[lvl_i]
            if np.any(lvl.lo[node] > qhi) or np.any(lvl.hi[node] < qlo):
                continue
            c0 = int(lvl.child[node])
            cn = int(lvl.count[node])
            if lvl_i == 0:
                # children are positions into the STR-ordered leaf list
                for j in range(c0, c0 + cn):
                    leaf = int(self.leaf_level_children[j])
                    if (not np.any(self.leaf_lo[leaf] > qhi)
                            and not np.any(self.leaf_hi[leaf] < qlo)):
                        hits.append(leaf)
            else:
                stack.extend((lvl_i - 1, j) for j in range(c0, c0 + cn))
        return np.asarray(hits, dtype=np.int64)

    def candidate_segments(self, qseg: np.ndarray, d: float) -> np.ndarray:
        """Global segment indices whose leaf MBB intersects the d-expanded
        MBB of one packed query segment (search phase)."""
        qlo = np.array([min(qseg[0], qseg[3]) - d, min(qseg[1], qseg[4]) - d,
                        min(qseg[2], qseg[5]) - d, qseg[6]])
        qhi = np.array([max(qseg[0], qseg[3]) + d, max(qseg[1], qseg[4]) + d,
                        max(qseg[2], qseg[5]) + d, qseg[7]])
        leaves = self._search_leaves(qlo, qhi)
        if leaves.size == 0:
            return np.zeros(0, np.int64)
        parts = [self.seg_order[self.leaf_first[lf]:
                                self.leaf_first[lf] + self.leaf_count[lf]]
                 for lf in leaves]
        return np.concatenate(parts)


def _refine(db_packed: np.ndarray, db: SegmentArray, cand: np.ndarray,
            qseg: np.ndarray, q_global: int, d: float) -> ResultSet | None:
    if cand.size == 0:
        return None
    t_enter, t_exit, hit = ops.interaction_tiles(
        db_packed[cand], qseg[None, :], np.float32(d), use_pallas=False)
    hit = np.asarray(hit)[:, 0]
    if not hit.any():
        return None
    rows = np.nonzero(hit)[0]
    eg = cand[rows]
    return ResultSet(
        entry_idx=eg.astype(np.int64),
        entry_traj=db.traj_id[eg].astype(np.int64),
        entry_seg=db.seg_id[eg].astype(np.int64),
        query_idx=np.full(rows.size, q_global, np.int64),
        t_enter=np.asarray(t_enter)[rows, 0],
        t_exit=np.asarray(t_exit)[rows, 0],
    )


class RTreeEngine:
    """Search-and-refine distance-threshold engine (the CPU baseline)."""

    def __init__(self, db: SegmentArray, r: int = 12, fanout: int = 16):
        self.db = db if db.is_sorted() else db.sort_by_tstart()
        self.tree = RTree(self.db, r=r, fanout=fanout)
        self._packed = self.db.packed()

    def query(self, queries: SegmentArray, d: float) -> ResultSet:
        q_packed = queries.packed()
        parts = []
        for qi in range(len(queries)):
            cand = self.tree.candidate_segments(q_packed[qi], d)
            rs = _refine(self._packed, self.db, cand, q_packed[qi], qi, d)
            if rs is not None:
                parts.append(rs)
        return ResultSet.concatenate(parts).sorted_canonical()

    def query_parallel(self, queries: SegmentArray, d: float,
                       num_threads: int = 4) -> ResultSet:
        q_packed = queries.packed()

        def one(qi: int) -> ResultSet | None:
            cand = self.tree.candidate_segments(q_packed[qi], d)
            return _refine(self._packed, self.db, cand, q_packed[qi], qi, d)

        with ThreadPoolExecutor(num_threads) as pool:
            parts = [r for r in pool.map(one, range(len(queries)))
                     if r is not None]
        return ResultSet.concatenate(parts).sorted_canonical()
