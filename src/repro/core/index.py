"""Temporal-bin index (paper §4).

Entry segments, sorted by non-decreasing ``t_start``, are logically divided
into ``m`` fixed-width temporal bins.  Bin ``B_j`` is fully described by
``(B_start, B_end, B_first, B_last)``:

* ``B_start[j] = t0 + j*b`` where ``b = (t_max - t0) / m``;
* ``B_end[j]   = max over segments in bin of t_end`` (−inf if empty);
* ``B_first[j]`` / ``B_last[j]``: first/last segment index with
  ``t_start`` in ``[B_start[j], B_start[j]+b)``.

For a query with temporal extent ``[qt0, qt1]`` the set of overlapping bins
is contiguous, and the candidate entry segments are exactly the contiguous
index range ``[first, last]`` — this contiguity is what makes the search a
dense streaming computation on the accelerator.

The paper finds the overlapping bins with an index-tree over bin extents in
O(log m); we use the equivalent binary search over the prefix-max of
``B_end`` (non-decreasing, hence searchable) — same complexity, no tree.

**Spatial pruning (PR 5).**  The paper's index is purely temporal: every
segment in the contiguous range is a candidate even when it is spatially
nowhere near the query (the follow-up work, arXiv:1410.2698, shows spatial
pruning is the next win).  Each bin therefore also carries a spatial MBR —
the axis-aligned box over its segments' endpoint boxes (a linearly moving
segment never leaves that box) — plus running prefix/suffix MBR unions.
:meth:`candidate_subranges` *trims and splits* a query's contiguous
``[first, last]`` range into the sub-ranges whose bin MBRs lie within the
(conservatively inflated) threshold of the query's MBR; a bin farther than
``d`` from every query in the batch cannot contribute a hit, so dropping
it is exact, never lossy.  :meth:`estimate_pruned_candidates_batch` is the
vectorized *pricing* counterpart over a coarsened bin grid — cheap enough
for the SETSPLIT merge loops, conservative (it never under-counts the
exact pruned workload).

**Hierarchical K-box layer (PR 7).**  One box per bin unions multi-modal
activity (two swarms far apart in the same epoch) into one fat box that
prunes nothing.  Each bin's segments are therefore additionally split into
at most ``K`` spatial boxes: segments are *reordered within their bin* by
midpoint coordinate along the bin's widest-spread axis (the permutation is
stored as :attr:`perm`; bins stay contiguous, so every bin-granular
quantity — ``b_first``/``b_last``/``b_end``/per-bin MBRs — is invariant),
and each bin is cut at its ``K−1`` largest coordinate gaps.  Every
(bin, box) slot is then a *contiguous sub-range of permuted segment
indices* with its own MBR, so :meth:`candidate_subranges(level="box")`
prunes at box granularity with the exact same inflated-threshold test —
still never lossy.  Engines keep their segment arrays t_start-sorted
(the distributed pod partition depends on it) and permute only the packed
device copy; result entry indices are mapped back through :attr:`perm`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.segments import SegmentArray

DEFAULT_NUM_BINS = 10_000  # paper §7.2: "the number of entry bins ... is set to 10,000"

#: Coarse pricing-grid resolution: per-bin MBRs are unioned into at most
#: this many coarse bins for the vectorized pruned-count estimate (the
#: merge loops evaluate it per adjacent pair per iteration).
COARSE_GRID_BINS = 128

#: Max sub-ranges :meth:`candidate_subranges` returns per query extent —
#: each sub-range becomes one dispatched batch, so this bounds the
#: dispatch-count blow-up; surplus runs merge across the smallest gaps.
#: ``ExecutionPolicy.max_subranges`` overrides this per query; the coarse
#: pricing grid prices the re-admission cost of the cap (see
#: :meth:`TemporalBinIndex.estimate_pruned_candidates_batch`).
DEFAULT_MAX_SUBRANGES = 8

#: Hard ceiling on the per-bin spatial split factor K.  The K-box arrays
#: are dense ``(num_bins, K, …)``, so K is kept small; beyond ~8 boxes the
#: per-bin split stops paying for its planning cost anyway.
MAX_KBOXES = 8


def mbr_gap2(alo, ahi, blo, bhi):
    """Squared minimum distance between axis-aligned boxes (broadcasts
    over leading dims; the last dim is the 3 spatial axes).  Empty boxes
    (``lo=+inf, hi=-inf``) yield ``inf`` — always pruned."""
    g = np.maximum(np.maximum(blo - ahi, alo - bhi), 0.0)
    return np.sum(g * g, axis=-1)


def _coalesce_runs(kf: np.ndarray, kl: np.ndarray,
                   first: int, last: int) -> list[list[int]]:
    """Clamp surviving box ranges into ``[first, last]`` and coalesce
    adjacent boxes with no segments between them into maximal runs.

    ``kf``/``kl`` are inclusive (first, last) ranges in non-decreasing
    ``kf`` order; a new run starts only where a real index gap remains
    after the running-max of ``kl`` (boxes can nest after clamping)."""
    if kf.size == 0:
        return []
    kf = np.maximum(kf, first)
    kl = np.minimum(kl, last)
    ok = kl >= kf
    kf, kl = kf[ok], kl[ok]
    if kf.size == 0:
        return []
    cummax = np.maximum.accumulate(kl)
    newrun = np.r_[True, kf[1:] > cummax[:-1] + 1]
    starts = kf[newrun]
    ends = np.maximum.reduceat(kl, np.nonzero(newrun)[0])
    return [[int(a), int(b)] for a, b in zip(starts, ends)]


def prune_limit(d: float, scale: float) -> float:
    """Conservatively inflated threshold for MBR pruning.

    The kernels decide hits in float32; the quadratic coefficient
    ``c = |Δr|² − d²`` carries an absolute round-off ~``eps32·scale²``
    (``scale`` = largest coordinate magnitude), which can make a pair whose
    true minimum distance slightly exceeds ``d`` register as a hit.  The
    pruning test must keep such pairs, so the threshold is inflated by the
    distance overshoot that error can cause: ``err/(2d)`` in the smooth
    regime, ``sqrt(err)`` when ``d`` is tiny.  Exactness of pruning (no
    dropped hit, ever) only needs the slack to be an upper bound; the
    over-inflation costs a negligible amount of pruning.
    """
    d = float(d)
    err = 4e-6 * scale * scale
    slack = min(err / max(2.0 * d, 1e-12), float(np.sqrt(err)))
    return d + 1e-5 * d + slack + 1e-9


@dataclasses.dataclass
class TemporalBinIndex:
    """The bin description arrays + the sorted segment t arrays they index."""

    t0: float
    bin_width: float
    num_bins: int
    b_start: np.ndarray      # (m,) float64 — bin start times
    b_end: np.ndarray        # (m,) float64 — max t_end in bin, −inf if empty
    b_first: np.ndarray      # (m,) int64 — first segment index in bin
    b_last: np.ndarray       # (m,) int64 — last segment index in bin (first-1 if empty)
    _bend_prefix_max: np.ndarray  # (m,) float64 — running max of b_end
    n_segments: int
    # -- spatial pruning layer (PR 5) ----------------------------------
    mbr_lo: np.ndarray       # (m, 3) float64 — per-bin MBR min (+inf if empty)
    mbr_hi: np.ndarray       # (m, 3) float64 — per-bin MBR max (−inf if empty)
    prefix_lo: np.ndarray    # (m, 3) — union MBR of bins [0, j]
    prefix_hi: np.ndarray
    suffix_lo: np.ndarray    # (m, 3) — union MBR of bins [j, m)
    suffix_hi: np.ndarray
    _prune_scale: float      # largest |coordinate| in the db (slack sizing)
    _coarse_first: np.ndarray  # (k,) int64 — coarse-bin segment ranges
    _coarse_last: np.ndarray
    _coarse_lo: np.ndarray     # (k, 3) — coarse-bin union MBRs
    _coarse_hi: np.ndarray
    # -- hierarchical K-box layer (PR 7) --------------------------------
    kboxes: int = 1          # per-bin spatial split factor K (1 = PR 5 index)
    perm: np.ndarray | None = None  # (n,) within-bin reorder: new[i] = old[perm[i]]
    kbox_first: np.ndarray | None = None  # (m, K) int64 — per-box permuted ranges
    kbox_last: np.ndarray | None = None   # (m, K) int64 — first-1 / -1 when empty
    kbox_lo: np.ndarray | None = None     # (m, K, 3) — per-box MBR (+inf empty)
    kbox_hi: np.ndarray | None = None     # (m, K, 3)
    _coarse_klo: np.ndarray | None = None  # (k, K, 3) — coarse K-box unions
    _coarse_khi: np.ndarray | None = None

    # ------------------------------------------------------------------
    @staticmethod
    def build(db: SegmentArray, num_bins: int = DEFAULT_NUM_BINS, *,
              kboxes: int = 1) -> "TemporalBinIndex":
        if not db.is_sorted():
            raise ValueError("TemporalBinIndex requires segments sorted by t_start")
        n = len(db)
        if n == 0:
            raise ValueError("cannot index an empty database")
        ts = db.ts.astype(np.float64)
        te = db.te.astype(np.float64)
        t0 = float(ts[0])
        t_max = float(max(ts.max(), te.max()))
        # Degenerate all-at-one-instant databases still get one valid bin.
        width = max((t_max - t0) / num_bins, np.finfo(np.float64).tiny)

        b_start = t0 + width * np.arange(num_bins, dtype=np.float64)
        edges = t0 + width * np.arange(num_bins + 1, dtype=np.float64)
        # b_first[j] = first i with ts[i] >= edge[j]; b_last[j] = b_first[j+1]-1.
        firsts = np.searchsorted(ts, edges, side="left")
        # Segments with ts == t_max would land in bin m; clamp into the last bin
        # (paper's floor(t/b) with t = t_max edge case).
        firsts[-1] = n
        b_first = firsts[:-1].astype(np.int64)
        b_last = (firsts[1:] - 1).astype(np.int64)

        b_end = np.full(num_bins, -np.inf, dtype=np.float64)
        seg_lo, seg_hi = db.mbrs()
        mbr_lo = np.full((num_bins, 3), np.inf, dtype=np.float64)
        mbr_hi = np.full((num_bins, 3), -np.inf, dtype=np.float64)
        nonempty = b_last >= b_first
        # Per-bin max of te (and min/max of the endpoint boxes) via
        # reduceat over the sorted layout.
        if nonempty.any():
            starts = b_first[nonempty]
            seg_max = np.maximum.reduceat(te, starts)
            # reduceat reduces [starts[k], starts[k+1]) — but consecutive
            # non-empty bins may be separated by empty ones whose range is
            # empty; since starts are the b_first of non-empty bins and the
            # next non-empty bin's b_first equals this bin's b_last+1 (empty
            # bins in between contribute no indices), the reduction ranges
            # are exactly the bins' segment ranges, except the final range
            # runs to n which is also correct.
            b_end[nonempty] = seg_max
            mbr_lo[nonempty] = np.minimum.reduceat(seg_lo, starts, axis=0)
            mbr_hi[nonempty] = np.maximum.reduceat(seg_hi, starts, axis=0)
        prefix_max = np.maximum.accumulate(b_end)
        # Running MBR unions (±inf empty boxes are the min/max identities):
        # prefix[j] covers bins [0, j], suffix[j] covers bins [j, m) — a
        # query range [j_lo, j_hi] is a subset of both, so the larger of
        # the two box distances lower-bounds the distance to the range's
        # true union (the whole-range quick reject in candidate_subranges).
        prefix_lo = np.minimum.accumulate(mbr_lo, axis=0)
        prefix_hi = np.maximum.accumulate(mbr_hi, axis=0)
        suffix_lo = np.minimum.accumulate(mbr_lo[::-1], axis=0)[::-1].copy()
        suffix_hi = np.maximum.accumulate(mbr_hi[::-1], axis=0)[::-1].copy()
        scale = float(max(np.abs(seg_lo).max(), np.abs(seg_hi).max(), 1.0))
        # Coarse pricing grid: chunks of fine bins unioned down to at most
        # COARSE_GRID_BINS boxes; chunk c's segment range is contiguous
        # because the fine bins partition the sorted segment array.
        chunk = max((num_bins + COARSE_GRID_BINS - 1) // COARSE_GRID_BINS, 1)
        cstarts = np.arange(0, num_bins, chunk, dtype=np.int64)
        cends = np.minimum(cstarts + chunk - 1, num_bins - 1)
        coarse_lo = np.minimum.reduceat(mbr_lo, cstarts, axis=0)
        coarse_hi = np.maximum.reduceat(mbr_hi, cstarts, axis=0)

        # -- hierarchical K-box layer (PR 7) ----------------------------
        kboxes = int(kboxes)
        if not 1 <= kboxes <= MAX_KBOXES:
            raise ValueError(f"kboxes must be in [1, {MAX_KBOXES}], got {kboxes}")
        if kboxes == 1:
            # K=1 is exactly the PR 5 index: one box per bin, no reorder.
            perm = None
            kbox_first = b_first[:, None].copy()
            kbox_last = b_last[:, None].copy()
            kbox_lo = mbr_lo[:, None, :].copy()
            kbox_hi = mbr_hi[:, None, :].copy()
        else:
            counts = np.maximum(b_last - b_first + 1, 0)
            bin_id = np.repeat(np.arange(num_bins, dtype=np.int64), counts)
            mid = 0.5 * (seg_lo + seg_hi)
            # Per-bin widest-spread midpoint axis: splitting along it
            # separates spatial modes; ties/empty default to axis 0.
            axis = np.zeros(num_bins, dtype=np.int64)
            if nonempty.any():
                mmin = np.minimum.reduceat(mid, starts, axis=0)
                mmax = np.maximum.reduceat(mid, starts, axis=0)
                axis[nonempty] = np.argmax(mmax - mmin, axis=1)
            key = mid[np.arange(n), axis[bin_id]]
            # Stable within-bin sort by the split-axis coordinate: bins
            # stay contiguous, so every bin-granular quantity above is
            # unchanged, and each spatial box becomes a contiguous
            # sub-range of permuted indices.
            perm = np.lexsort((key, bin_id)).astype(np.int64)
            keyp = key[perm]
            # Split each bin at its kboxes-1 largest strictly-positive
            # coordinate gaps (equal-count quantiles would cut through a
            # lopsided mode; largest-gap cuts between modes).
            splits = np.empty(0, dtype=np.int64)
            if n > 1:
                gapv = keyp[1:] - keyp[:-1]
                cand = (bin_id[1:] == bin_id[:-1]) & (gapv > 0.0)
                cpos = np.nonzero(cand)[0].astype(np.int64) + 1
                if cpos.size:
                    cgap = gapv[cpos - 1]
                    cbin = bin_id[cpos]
                    order = np.lexsort((-cgap, cbin))
                    sb = cbin[order]
                    grp = np.r_[0, np.nonzero(np.diff(sb))[0] + 1].astype(np.int64)
                    lens = np.diff(np.r_[grp, sb.size])
                    rank = np.arange(sb.size) - np.repeat(grp, lens)
                    splits = np.sort(cpos[order[rank < kboxes - 1]])
            # Box slots: each box starts at its bin's b_first or at a
            # split position; non-empty bins tile [0, n) contiguously, so
            # each box ends right before the next start.
            allstarts = np.concatenate([b_first[nonempty], splits])
            allstarts.sort()
            abin = bin_id[allstarts]
            grp = np.r_[0, np.nonzero(np.diff(abin))[0] + 1].astype(np.int64)
            lens = np.diff(np.r_[grp, abin.size])
            bidx = np.arange(abin.size) - np.repeat(grp, lens)
            ends = np.r_[allstarts[1:] - 1, n - 1].astype(np.int64)
            slo_p, shi_p = seg_lo[perm], seg_hi[perm]
            box_lo = np.minimum.reduceat(slo_p, allstarts, axis=0)
            box_hi = np.maximum.reduceat(shi_p, allstarts, axis=0)
            kbox_first = np.zeros((num_bins, kboxes), dtype=np.int64)
            kbox_last = np.full((num_bins, kboxes), -1, dtype=np.int64)
            kbox_lo = np.full((num_bins, kboxes, 3), np.inf)
            kbox_hi = np.full((num_bins, kboxes, 3), -np.inf)
            kbox_first[abin, bidx] = allstarts
            kbox_last[abin, bidx] = ends
            kbox_lo[abin, bidx] = box_lo
            kbox_hi[abin, bidx] = box_hi
        # Coarse pricing grid, K-box flavour: cell c's box k is the union
        # over the chunk's bins of their box k.  If any fine box (j, k)
        # survives the prune test its cell's box k contains it and
        # survives too, so the coarse estimate stays conservative.
        coarse_klo = np.minimum.reduceat(kbox_lo, cstarts, axis=0)
        coarse_khi = np.maximum.reduceat(kbox_hi, cstarts, axis=0)
        return TemporalBinIndex(
            t0=t0, bin_width=width, num_bins=num_bins,
            b_start=b_start, b_end=b_end, b_first=b_first, b_last=b_last,
            _bend_prefix_max=prefix_max, n_segments=n,
            mbr_lo=mbr_lo, mbr_hi=mbr_hi,
            prefix_lo=prefix_lo, prefix_hi=prefix_hi,
            suffix_lo=suffix_lo, suffix_hi=suffix_hi,
            _prune_scale=scale,
            _coarse_first=b_first[cstarts], _coarse_last=b_last[cends],
            _coarse_lo=coarse_lo, _coarse_hi=coarse_hi,
            kboxes=kboxes, perm=perm,
            kbox_first=kbox_first, kbox_last=kbox_last,
            kbox_lo=kbox_lo, kbox_hi=kbox_hi,
            _coarse_klo=coarse_klo, _coarse_khi=coarse_khi,
        )

    # ------------------------------------------------------------------
    def bin_of(self, t_start: float) -> int:
        """floor((t_start - t0)/b), clamped into [0, m-1] (paper's bin rule)."""
        j = int(np.floor((t_start - self.t0) / self.bin_width))
        return min(max(j, 0), self.num_bins - 1)

    def _bin_range(self, qt0: float, qt1: float) -> tuple[int, int] | None:
        """Contiguous overlapping-bin range [j_lo, j_hi], or None."""
        if qt1 < qt0:
            return None
        j_hi = int(np.floor((qt1 - self.t0) / self.bin_width))
        if j_hi < 0:
            return None
        j_hi = min(j_hi, self.num_bins - 1)
        # Earliest bin whose B_end reaches qt0: prefix-max is non-decreasing
        # so binary search is valid; prefix_max[j] >= qt0 first holds at the
        # earliest overlapping bin itself.
        j_lo = int(np.searchsorted(self._bend_prefix_max, qt0, side="left"))
        if j_lo > j_hi:
            return None
        return j_lo, j_hi

    def candidate_range(self, qt0: float, qt1: float) -> tuple[int, int]:
        """Contiguous candidate index range [first, last] for query extent
        [qt0, qt1].  Returns (0, -1) when no candidates exist.

        Overlapping bins are those with ``B_start <= qt1`` and
        ``B_end >= qt0``; the range is then
        ``[min B_first, max B_last]`` over that (contiguous) set.  The
        range is clamped into ``[0, n_segments)`` — a query outlasting the
        database extent must price (and dispatch) only real segments.
        """
        r = self._bin_range(qt0, qt1)
        if r is None:
            return (0, -1)
        j_lo, j_hi = r
        # min B_first over bins [j_lo, j_hi]: b_first is non-decreasing.
        first = max(int(self.b_first[j_lo]), 0)
        last = min(int(self.b_last[j_hi]), self.n_segments - 1)
        if last < first:
            return (0, -1)
        return first, last

    def num_candidates(self, qt0: float, qt1: float) -> int:
        first, last = self.candidate_range(qt0, qt1)
        return max(last - first + 1, 0)

    def candidate_range_batch(self, qt0: np.ndarray, qt1: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized :meth:`candidate_range` over arrays of query extents.

        Returns ``(first, last)`` int64 arrays; empty ranges are encoded as
        ``last < first`` (specifically first=0, last=-1).  This is the
        workhorse of the SETSPLIT algorithms, which evaluate ``numInts`` for
        every adjacent batch pair on every merge iteration.
        """
        qt0 = np.asarray(qt0, dtype=np.float64)
        qt1 = np.asarray(qt1, dtype=np.float64)
        j_hi = np.floor((qt1 - self.t0) / self.bin_width).astype(np.int64)
        valid = (qt1 >= qt0) & (j_hi >= 0)
        j_hi = np.clip(j_hi, 0, self.num_bins - 1)
        j_lo = np.searchsorted(self._bend_prefix_max, qt0, side="left").astype(np.int64)
        valid &= j_lo <= j_hi
        j_lo = np.minimum(j_lo, self.num_bins - 1)
        # Clamp into [0, n_segments) — same contract as candidate_range.
        first = np.maximum(self.b_first[j_lo], 0)
        last = np.minimum(self.b_last[j_hi], self.n_segments - 1)
        valid &= last >= first
        first = np.where(valid, first, 0)
        last = np.where(valid, last, -1)
        return first, last

    def num_candidates_batch(self, qt0: np.ndarray, qt1: np.ndarray) -> np.ndarray:
        first, last = self.candidate_range_batch(qt0, qt1)
        return np.maximum(last - first + 1, 0)

    def num_interactions(self, qt0: float, qt1: float, batch_size: int) -> int:
        """|Q_batch| × |E_Q| — the paper's interaction count for one batch."""
        return batch_size * self.num_candidates(qt0, qt1)

    # ------------------------------------------------------------------
    def bins_overlapping(self, qt0: float, qt1: float) -> np.ndarray:
        """Indices of bins that temporally overlap [qt0, qt1] (for tests)."""
        mask = (self.b_start <= qt1) & (self.b_end >= qt0)
        return np.nonzero(mask)[0]

    # ------------------------------------------------------------------
    # spatial pruning (PR 5)
    # ------------------------------------------------------------------
    def _limit(self, d: float, qlo: np.ndarray, qhi: np.ndarray) -> float:
        """The inflated prune threshold for one query MBR (or a stack)."""
        finite = np.isfinite(qlo) & np.isfinite(qhi)
        qscale = (float(max(np.abs(qlo[finite]).max(initial=0.0),
                            np.abs(qhi[finite]).max(initial=0.0)))
                  if finite.any() else 0.0)
        return prune_limit(d, max(self._prune_scale, qscale))

    def _bin_runs(self, bins: slice, j_lo: int, qt0: float, qlo, qhi,
                  lim2: float, first: int, last: int) -> list[list[int]]:
        """Surviving bin runs as [first, last] segment sub-ranges (PR 5)."""
        gap2 = mbr_gap2(self.mbr_lo[bins], self.mbr_hi[bins], qlo, qhi)
        keep = (gap2 <= lim2) & (self.b_end[bins] >= qt0)
        kept = np.nonzero(keep)[0]
        if kept.size == 0:
            return []
        # Runs of consecutive kept bins -> segment sub-ranges.  Adjacent
        # sub-ranges with no segments between them coalesce: a pruned bin
        # that is *empty* (or whose segments all sit left of the range)
        # separates runs in bin space but not in segment space, and
        # splitting there would fragment the plan for zero pruned work
        # (e.g. integer-aligned segment starts against a finer bin grid
        # leave every fifth bin empty).
        breaks = np.nonzero(np.diff(kept) > 1)[0]
        run_a = np.concatenate([[0], breaks + 1])
        run_b = np.concatenate([breaks, [kept.size - 1]])
        subs: list[list[int]] = []
        for a, b in zip(kept[run_a], kept[run_b]):
            f = max(int(self.b_first[j_lo + a]), first)
            l = min(int(self.b_last[j_lo + b]), last)
            if l < f:
                continue
            if subs and f <= subs[-1][1] + 1:
                subs[-1][1] = max(subs[-1][1], l)
            else:
                subs.append([f, l])
        return subs

    def _box_runs(self, bins: slice, qt0: float, qlo, qhi,
                  lim2: float, first: int, last: int) -> list[list[int]]:
        """Surviving K-box runs as [first, last] *permuted* sub-ranges.

        Kept boxes in (bin-major, box-minor) order are increasing in
        permuted segment position, so runs form by coalescing adjacent
        boxes with no segments between them — same rule as the bin level,
        vectorized because a long extent can keep num_bins×K boxes.
        Empty box slots carry ±inf MBRs, so ``gap2 = inf`` prunes them.
        """
        gap2 = mbr_gap2(self.kbox_lo[bins], self.kbox_hi[bins], qlo, qhi)
        keep = (gap2 <= lim2) & (self.b_end[bins] >= qt0)[:, None]
        kf = self.kbox_first[bins][keep]
        kl = self.kbox_last[bins][keep]
        return _coalesce_runs(kf, kl, first, last)

    def candidate_subranges(self, qt0: float, qt1: float,
                            qlo: np.ndarray, qhi: np.ndarray, d: float, *,
                            max_subranges: int = DEFAULT_MAX_SUBRANGES,
                            level: str = "bin") -> list[tuple[int, int]]:
        """Spatially pruned candidate sub-ranges for one query extent.

        ``qlo``/``qhi`` is the (3,) union MBR of the query segments sharing
        the extent ``[qt0, qt1]`` (a batch); ``d`` the distance threshold.
        Returns disjoint, increasing, inclusive ``(first, last)`` segment
        index sub-ranges — the temporal ``candidate_range`` with every run
        of bins (``level="bin"``, PR 5) or per-bin K-boxes
        (``level="box"``, PR 7 — sub-ranges are then *permuted* segment
        positions, matching the engines' permuted packed layout) farther
        than the inflated threshold from the query MBR (or temporally
        dead: ``B_end < qt0``) cut out.  Exact: a pruned box lies farther
        than ``d`` from the whole batch MBR, hence from every member
        query's box, hence from every member query at every instant — no
        hit can be dropped.  At most ``max_subranges`` runs come back
        (surplus runs merge across the *smallest* gaps; merging re-admits
        the gap's segments, so the cap trades dispatch count for pruned
        work — pruning may only shrink, never grow, the result, and on
        multi-modal extents a too-small cap silently merges across huge
        gaps, which is why the cap is policy-tunable and priced by
        :meth:`estimate_pruned_candidates_batch`).
        """
        r = self._bin_range(qt0, qt1)
        if r is None:
            return []
        j_lo, j_hi = r
        first = max(int(self.b_first[j_lo]), 0)
        last = min(int(self.b_last[j_hi]), self.n_segments - 1)
        if last < first:
            return []
        qlo = np.asarray(qlo, np.float64)
        qhi = np.asarray(qhi, np.float64)
        lim = self._limit(d, qlo, qhi)
        lim2 = lim * lim
        # Whole-range quick reject: the range's true MBR union is a subset
        # of both prefix[j_hi] and suffix[j_lo], so the larger box distance
        # lower-bounds the distance to everything in the range.
        lb2 = max(float(mbr_gap2(self.prefix_lo[j_hi], self.prefix_hi[j_hi],
                                 qlo, qhi)),
                  float(mbr_gap2(self.suffix_lo[j_lo], self.suffix_hi[j_lo],
                                 qlo, qhi)))
        if lb2 > lim2:
            return []
        bins = slice(j_lo, j_hi + 1)
        if level == "box":
            subs = self._box_runs(bins, qt0, qlo, qhi, lim2, first, last)
        else:
            subs = self._bin_runs(bins, j_lo, qt0, qlo, qhi, lim2,
                                  first, last)
        if len(subs) > max_subranges:
            # Keep only the largest inter-run gaps as split points; merging
            # across a gap re-admits the gap's segments (exactness is
            # preserved — pruning may only shrink, never grow, the result).
            gaps = np.array([subs[i + 1][0] - subs[i][1]
                             for i in range(len(subs) - 1)])
            keep = max(int(max_subranges) - 1, 0)
            splits = (set(np.argsort(gaps)[-keep:].tolist()) if keep
                      else set())
            merged = [subs[0]]
            for i, s in enumerate(subs[1:]):
                if i in splits:
                    merged.append(s)
                else:
                    merged[-1][1] = s[1]
            subs = merged
        return [(int(f), int(l)) for f, l in subs]

    def pruned_num_candidates(self, qt0: float, qt1: float, qlo, qhi,
                              d: float, *,
                              max_subranges: int = DEFAULT_MAX_SUBRANGES,
                              level: str = "bin") -> int:
        """Exact candidate count surviving :meth:`candidate_subranges`."""
        return sum(l - f + 1 for f, l in
                   self.candidate_subranges(qt0, qt1, qlo, qhi, d,
                                            max_subranges=max_subranges,
                                            level=level))

    def estimate_pruned_candidates_batch(self, qt0, qt1, qlo, qhi,
                                         d: float, *,
                                         level: str = "bin",
                                         max_subranges: int | None = None
                                         ) -> np.ndarray:
        """Vectorized pruned-candidate estimate over the coarse bin grid.

        ``qt0``/``qt1`` are (n,) extents, ``qlo``/``qhi`` (n, 3) query-MBR
        stacks.  For each row, the temporal ``[first, last]`` range is
        intersected with every coarse bin's segment range and coarse bins
        whose union MBR lies beyond the inflated threshold are dropped.
        ``level="box"`` keeps a cell only when *some* of its K coarse
        boxes survives — a strictly sharper (still conservative) test on
        multi-modal data, matching ``candidate_subranges(level="box")``.
        Conservative with respect to the *uncapped* sub-range split (a
        coarse union prunes no more than its fine bins) and exactly equal
        to the temporal count when nothing is spatially pruned.  Passing
        ``max_subranges`` additionally prices the sub-range cap: surplus
        fragments merge across gaps and re-admit the gap's segments, so
        the estimate adds ``internal_dropped × excess/(fragments−1)`` —
        the expected re-admission if the cap merges a proportional share
        of the internal gaps — keeping the pricing signal honest on
        heavily fragmented extents instead of silently under-counting
        them.  This is the signal the SETSPLIT/GREEDYSETSPLIT merge loops
        consume.
        """
        qt0 = np.asarray(qt0, np.float64)
        qt1 = np.asarray(qt1, np.float64)
        qlo = np.asarray(qlo, np.float64).reshape(-1, 3)
        qhi = np.asarray(qhi, np.float64).reshape(-1, 3)
        first, last = self.candidate_range_batch(qt0, qt1)
        cf, cl = self._coarse_first, self._coarse_last
        ov = (np.minimum(last[:, None], cl[None, :])
              - np.maximum(first[:, None], cf[None, :]) + 1)
        ov = np.maximum(ov, 0)
        lim = self._limit(float(d), qlo, qhi)
        if level == "box":
            gap2 = mbr_gap2(self._coarse_klo[None], self._coarse_khi[None],
                            qlo[:, None, None], qhi[:, None, None])  # (n,k,K)
            keep = (gap2 <= lim * lim).any(axis=2)
        else:
            gap2 = mbr_gap2(self._coarse_lo[None], self._coarse_hi[None],
                            qlo[:, None], qhi[:, None])     # (n, k)
            keep = gap2 <= lim * lim
        est = (ov * keep).sum(axis=1).astype(np.int64)
        if max_subranges is not None:
            kk = keep & (ov > 0)
            ncell = kk.shape[1]
            frag = kk[:, 0].astype(np.int64) + (kk[:, 1:] & ~kk[:, :-1]).sum(axis=1)
            any_k = kk.any(axis=1)
            idx = np.arange(ncell)
            first_k = np.where(any_k, kk.argmax(axis=1), ncell)
            last_k = np.where(any_k, ncell - 1 - kk[:, ::-1].argmax(axis=1), -1)
            internal = (~kk) & (idx[None, :] > first_k[:, None]) \
                & (idx[None, :] < last_k[:, None])
            dropped = (ov * internal).sum(axis=1).astype(np.int64)
            excess = np.maximum(frag - int(max_subranges), 0)
            denom = np.maximum(frag - 1, 1)
            est = est + (dropped * excess + denom - 1) // denom
        return est

    # ------------------------------------------------------------------
    # pod-local K-box rebuild (PR 8)
    # ------------------------------------------------------------------
    def build_for_slice(self, db: SegmentArray, lo: int, hi: int,
                        kboxes: int | None = None):
        """Rebuild the K-box layer over one ownership slice ``[lo, hi]``.

        The distributed pod partition assigns each pod a contiguous slice
        of the t_start-sorted segment array; a pod must only ever reorder
        *its own* rows, so the PR 7 global within-bin split cannot be
        reused (its permutation moves segments across pod boundaries
        whenever a bin straddles one).  This path re-runs the PR 7 split
        — widest-spread midpoint axis, largest-gap cuts, at most ``K``
        boxes — independently per (bin ∩ slice) piece.  Because every
        piece lies inside one bin *and* one pod slice, the returned
        permutation leaves both bin ranges and pod ownership ranges
        occupying exactly their original index intervals.

        Returns ``(perm_slice, box_first, box_last, box_lo, box_hi,
        box_bin)``: ``perm_slice`` maps the slice's permuted positions
        ``lo..hi`` back to original segment indices; the flat box arrays
        are in increasing ``box_first`` (global permuted-position) order
        with ``box_bin`` the owning bin of each box (non-decreasing).
        """
        kboxes = self.kboxes if kboxes is None else int(kboxes)
        if not 1 <= kboxes <= MAX_KBOXES:
            raise ValueError(f"kboxes must be in [1, {MAX_KBOXES}], got {kboxes}")
        empty3 = np.empty((0, 3), dtype=np.float64)
        empty1 = np.empty(0, dtype=np.int64)
        if hi < lo:
            return empty1, empty1, empty1, empty3, empty3, empty1
        nloc = hi - lo + 1
        seg_lo, seg_hi = db.mbrs()
        slo = seg_lo[lo:hi + 1]
        shi = seg_hi[lo:hi + 1]
        # Bin of each owned segment by *index position* (non-empty bins
        # tile [0, n) and b_last is non-decreasing), immune to float
        # edge rounding in the t_start -> bin map.
        binv = np.searchsorted(self.b_last, np.arange(lo, hi + 1),
                               side="left").astype(np.int64)
        mid = 0.5 * (slo + shi)
        # Pieces = runs of equal bin inside the slice; per-piece
        # widest-spread midpoint axis, exactly as in the global build.
        pf = np.r_[0, np.nonzero(np.diff(binv))[0] + 1].astype(np.int64)
        mmin = np.minimum.reduceat(mid, pf, axis=0)
        mmax = np.maximum.reduceat(mid, pf, axis=0)
        axis = np.argmax(mmax - mmin, axis=1)
        piece_id = np.cumsum(np.r_[0, (np.diff(binv) != 0).astype(np.int64)])
        key = mid[np.arange(nloc), axis[piece_id]]
        # Stable within-piece sort: pieces (hence bins, hence the pod
        # slice itself) keep their index intervals.
        order = np.lexsort((key, binv))
        perm_slice = (np.arange(lo, hi + 1, dtype=np.int64))[order]
        keyp = key[order]
        splits = np.empty(0, dtype=np.int64)
        if nloc > 1 and kboxes > 1:
            gapv = keyp[1:] - keyp[:-1]
            cand = (binv[1:] == binv[:-1]) & (gapv > 0.0)
            cpos = np.nonzero(cand)[0].astype(np.int64) + 1
            if cpos.size:
                cgap = gapv[cpos - 1]
                cbin = binv[cpos]
                order2 = np.lexsort((-cgap, cbin))
                sb = cbin[order2]
                grp = np.r_[0, np.nonzero(np.diff(sb))[0] + 1].astype(np.int64)
                lens = np.diff(np.r_[grp, sb.size])
                rank = np.arange(sb.size) - np.repeat(grp, lens)
                splits = np.sort(cpos[order2[rank < kboxes - 1]])
        allstarts = np.concatenate([pf, splits])
        allstarts.sort()
        slo_p, shi_p = slo[order], shi[order]
        box_lo = np.minimum.reduceat(slo_p, allstarts, axis=0)
        box_hi = np.maximum.reduceat(shi_p, allstarts, axis=0)
        box_first = allstarts + lo
        box_last = np.r_[allstarts[1:] - 1, nloc - 1].astype(np.int64) + lo
        box_bin = binv[allstarts]
        return perm_slice, box_first, box_last, box_lo, box_hi, box_bin


@dataclasses.dataclass
class PodPartitionedIndex(TemporalBinIndex):
    """The PR 7 hierarchical index rebuilt over a pod partition (PR 8).

    Every bin-level quantity is inherited verbatim from the base index —
    the pod-local permutation reorders segments only *within*
    (bin ∩ pod-slice) pieces, so bin ranges, per-bin MBRs, prefix/suffix
    unions and the coarse pricing grid are all invariant, and so are the
    pod ownership intervals themselves (``_pod_lens`` keeps working on
    permuted coordinates unchanged).  Only the box layer differs: instead
    of a dense ``(num_bins, K)`` grid there is a flat sorted box list
    (a bin straddling a pod boundary owns up to ``K`` boxes *per pod*,
    which the dense grid cannot represent), binary-searched by bin in
    :meth:`_box_runs`.  ``perm`` composes all pod-local permutations into
    one global map, so caller-visible ``entry_idx`` never changes.
    """

    box_first: np.ndarray | None = None  # (B,) int64 — sorted permuted starts
    box_last: np.ndarray | None = None   # (B,) int64 — inclusive ends
    box_lo: np.ndarray | None = None     # (B, 3) — per-box MBR
    box_hi: np.ndarray | None = None
    box_bin: np.ndarray | None = None    # (B,) int64 — owning bin, non-decreasing

    @staticmethod
    def build_partitioned(base: "TemporalBinIndex", db: SegmentArray,
                          pod_slices, *, kboxes: int | None = None
                          ) -> "PodPartitionedIndex":
        """Compose :meth:`TemporalBinIndex.build_for_slice` over every pod
        ownership slice ``(lo, hi)`` (inclusive; empty slices allowed)."""
        kboxes = base.kboxes if kboxes is None else int(kboxes)
        n = base.n_segments
        perm = np.arange(n, dtype=np.int64)
        parts: list[tuple] = []
        for lo, hi in pod_slices:
            if hi < lo:
                continue
            ps, bf, bl, blo, bhi, bb = base.build_for_slice(
                db, int(lo), int(hi), kboxes=kboxes)
            perm[lo:hi + 1] = ps
            parts.append((bf, bl, blo, bhi, bb))
        if parts:
            box_first = np.concatenate([p[0] for p in parts])
            box_last = np.concatenate([p[1] for p in parts])
            box_lo = np.concatenate([p[2] for p in parts], axis=0)
            box_hi = np.concatenate([p[3] for p in parts], axis=0)
            box_bin = np.concatenate([p[4] for p in parts])
        else:
            box_first = box_last = box_bin = np.empty(0, dtype=np.int64)
            box_lo = box_hi = np.empty((0, 3), dtype=np.float64)
        # Pod slices arrive in increasing index order and bins increase
        # with index, so the flat lists are already box_first-sorted with
        # non-decreasing box_bin; assert rather than re-sort (a violation
        # means the pod partition overlapped, which must never happen).
        if box_first.size > 1:
            if not ((np.diff(box_first) > 0).all()
                    and (np.diff(box_bin) >= 0).all()):
                raise ValueError("pod slices must be disjoint and increasing")
        # Coarse pricing grid, K-box flavour, over the flat list: cell =
        # owning bin's coarse chunk, slot = within-cell rank mod K.  Any
        # (cell, slot) cover keeps the estimate conservative — a fine box
        # surviving the prune test keeps its containing union alive.
        chunk = max((base.num_bins + COARSE_GRID_BINS - 1)
                    // COARSE_GRID_BINS, 1)
        ncell = len(base._coarse_first)
        coarse_klo = np.full((ncell, kboxes, 3), np.inf)
        coarse_khi = np.full((ncell, kboxes, 3), -np.inf)
        if box_first.size:
            cell = box_bin // chunk
            grp = np.r_[0, np.nonzero(np.diff(cell))[0] + 1].astype(np.int64)
            lens = np.diff(np.r_[grp, cell.size])
            rank = np.arange(cell.size) - np.repeat(grp, lens)
            slot = rank % kboxes
            np.minimum.at(coarse_klo, (cell, slot), box_lo)
            np.maximum.at(coarse_khi, (cell, slot), box_hi)
        fields = {f.name: getattr(base, f.name)
                  for f in dataclasses.fields(TemporalBinIndex)}
        fields.update(
            kboxes=kboxes, perm=perm,
            # the dense (num_bins, K) grid is meaningless under the pod
            # permutation — null it out so stale reads fail loudly
            kbox_first=None, kbox_last=None, kbox_lo=None, kbox_hi=None,
            _coarse_klo=coarse_klo, _coarse_khi=coarse_khi,
        )
        return PodPartitionedIndex(
            **fields, box_first=box_first, box_last=box_last,
            box_lo=box_lo, box_hi=box_hi, box_bin=box_bin)

    def _box_runs(self, bins: slice, qt0: float, qlo, qhi,
                  lim2: float, first: int, last: int) -> list[list[int]]:
        """Flat-list override: binary-search the bin window, prune, and
        coalesce — identical semantics to the dense version."""
        a = int(np.searchsorted(self.box_bin, bins.start, side="left"))
        b = int(np.searchsorted(self.box_bin, bins.stop - 1, side="right"))
        if b <= a:
            return []
        bb = self.box_bin[a:b]
        gap2 = mbr_gap2(self.box_lo[a:b], self.box_hi[a:b], qlo, qhi)
        keep = (gap2 <= lim2) & (self.b_end[bb] >= qt0)
        kf = self.box_first[a:b][keep]
        kl = self.box_last[a:b][keep]
        return _coalesce_runs(kf, kl, first, last)
