"""Temporal-bin index (paper §4).

Entry segments, sorted by non-decreasing ``t_start``, are logically divided
into ``m`` fixed-width temporal bins.  Bin ``B_j`` is fully described by
``(B_start, B_end, B_first, B_last)``:

* ``B_start[j] = t0 + j*b`` where ``b = (t_max - t0) / m``;
* ``B_end[j]   = max over segments in bin of t_end`` (−inf if empty);
* ``B_first[j]`` / ``B_last[j]``: first/last segment index with
  ``t_start`` in ``[B_start[j], B_start[j]+b)``.

For a query with temporal extent ``[qt0, qt1]`` the set of overlapping bins
is contiguous, and the candidate entry segments are exactly the contiguous
index range ``[first, last]`` — this contiguity is what makes the search a
dense streaming computation on the accelerator.

The paper finds the overlapping bins with an index-tree over bin extents in
O(log m); we use the equivalent binary search over the prefix-max of
``B_end`` (non-decreasing, hence searchable) — same complexity, no tree.

**Spatial pruning (PR 5).**  The paper's index is purely temporal: every
segment in the contiguous range is a candidate even when it is spatially
nowhere near the query (the follow-up work, arXiv:1410.2698, shows spatial
pruning is the next win).  Each bin therefore also carries a spatial MBR —
the axis-aligned box over its segments' endpoint boxes (a linearly moving
segment never leaves that box) — plus running prefix/suffix MBR unions.
:meth:`candidate_subranges` *trims and splits* a query's contiguous
``[first, last]`` range into the sub-ranges whose bin MBRs lie within the
(conservatively inflated) threshold of the query's MBR; a bin farther than
``d`` from every query in the batch cannot contribute a hit, so dropping
it is exact, never lossy.  :meth:`estimate_pruned_candidates_batch` is the
vectorized *pricing* counterpart over a coarsened bin grid — cheap enough
for the SETSPLIT merge loops, conservative (it never under-counts the
exact pruned workload).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.segments import SegmentArray

DEFAULT_NUM_BINS = 10_000  # paper §7.2: "the number of entry bins ... is set to 10,000"

#: Coarse pricing-grid resolution: per-bin MBRs are unioned into at most
#: this many coarse bins for the vectorized pruned-count estimate (the
#: merge loops evaluate it per adjacent pair per iteration).
COARSE_GRID_BINS = 128

#: Max sub-ranges :meth:`candidate_subranges` returns per query extent —
#: each sub-range becomes one dispatched batch, so this bounds the
#: dispatch-count blow-up; surplus runs merge across the smallest gaps.
DEFAULT_MAX_SUBRANGES = 8


def mbr_gap2(alo, ahi, blo, bhi):
    """Squared minimum distance between axis-aligned boxes (broadcasts
    over leading dims; the last dim is the 3 spatial axes).  Empty boxes
    (``lo=+inf, hi=-inf``) yield ``inf`` — always pruned."""
    g = np.maximum(np.maximum(blo - ahi, alo - bhi), 0.0)
    return np.sum(g * g, axis=-1)


def prune_limit(d: float, scale: float) -> float:
    """Conservatively inflated threshold for MBR pruning.

    The kernels decide hits in float32; the quadratic coefficient
    ``c = |Δr|² − d²`` carries an absolute round-off ~``eps32·scale²``
    (``scale`` = largest coordinate magnitude), which can make a pair whose
    true minimum distance slightly exceeds ``d`` register as a hit.  The
    pruning test must keep such pairs, so the threshold is inflated by the
    distance overshoot that error can cause: ``err/(2d)`` in the smooth
    regime, ``sqrt(err)`` when ``d`` is tiny.  Exactness of pruning (no
    dropped hit, ever) only needs the slack to be an upper bound; the
    over-inflation costs a negligible amount of pruning.
    """
    d = float(d)
    err = 4e-6 * scale * scale
    slack = min(err / max(2.0 * d, 1e-12), float(np.sqrt(err)))
    return d + 1e-5 * d + slack + 1e-9


@dataclasses.dataclass
class TemporalBinIndex:
    """The bin description arrays + the sorted segment t arrays they index."""

    t0: float
    bin_width: float
    num_bins: int
    b_start: np.ndarray      # (m,) float64 — bin start times
    b_end: np.ndarray        # (m,) float64 — max t_end in bin, −inf if empty
    b_first: np.ndarray      # (m,) int64 — first segment index in bin
    b_last: np.ndarray       # (m,) int64 — last segment index in bin (first-1 if empty)
    _bend_prefix_max: np.ndarray  # (m,) float64 — running max of b_end
    n_segments: int
    # -- spatial pruning layer (PR 5) ----------------------------------
    mbr_lo: np.ndarray       # (m, 3) float64 — per-bin MBR min (+inf if empty)
    mbr_hi: np.ndarray       # (m, 3) float64 — per-bin MBR max (−inf if empty)
    prefix_lo: np.ndarray    # (m, 3) — union MBR of bins [0, j]
    prefix_hi: np.ndarray
    suffix_lo: np.ndarray    # (m, 3) — union MBR of bins [j, m)
    suffix_hi: np.ndarray
    _prune_scale: float      # largest |coordinate| in the db (slack sizing)
    _coarse_first: np.ndarray  # (k,) int64 — coarse-bin segment ranges
    _coarse_last: np.ndarray
    _coarse_lo: np.ndarray     # (k, 3) — coarse-bin union MBRs
    _coarse_hi: np.ndarray

    # ------------------------------------------------------------------
    @staticmethod
    def build(db: SegmentArray, num_bins: int = DEFAULT_NUM_BINS) -> "TemporalBinIndex":
        if not db.is_sorted():
            raise ValueError("TemporalBinIndex requires segments sorted by t_start")
        n = len(db)
        if n == 0:
            raise ValueError("cannot index an empty database")
        ts = db.ts.astype(np.float64)
        te = db.te.astype(np.float64)
        t0 = float(ts[0])
        t_max = float(max(ts.max(), te.max()))
        # Degenerate all-at-one-instant databases still get one valid bin.
        width = max((t_max - t0) / num_bins, np.finfo(np.float64).tiny)

        b_start = t0 + width * np.arange(num_bins, dtype=np.float64)
        edges = t0 + width * np.arange(num_bins + 1, dtype=np.float64)
        # b_first[j] = first i with ts[i] >= edge[j]; b_last[j] = b_first[j+1]-1.
        firsts = np.searchsorted(ts, edges, side="left")
        # Segments with ts == t_max would land in bin m; clamp into the last bin
        # (paper's floor(t/b) with t = t_max edge case).
        firsts[-1] = n
        b_first = firsts[:-1].astype(np.int64)
        b_last = (firsts[1:] - 1).astype(np.int64)

        b_end = np.full(num_bins, -np.inf, dtype=np.float64)
        seg_lo, seg_hi = db.mbrs()
        mbr_lo = np.full((num_bins, 3), np.inf, dtype=np.float64)
        mbr_hi = np.full((num_bins, 3), -np.inf, dtype=np.float64)
        nonempty = b_last >= b_first
        # Per-bin max of te (and min/max of the endpoint boxes) via
        # reduceat over the sorted layout.
        if nonempty.any():
            starts = b_first[nonempty]
            seg_max = np.maximum.reduceat(te, starts)
            # reduceat reduces [starts[k], starts[k+1]) — but consecutive
            # non-empty bins may be separated by empty ones whose range is
            # empty; since starts are the b_first of non-empty bins and the
            # next non-empty bin's b_first equals this bin's b_last+1 (empty
            # bins in between contribute no indices), the reduction ranges
            # are exactly the bins' segment ranges, except the final range
            # runs to n which is also correct.
            b_end[nonempty] = seg_max
            mbr_lo[nonempty] = np.minimum.reduceat(seg_lo, starts, axis=0)
            mbr_hi[nonempty] = np.maximum.reduceat(seg_hi, starts, axis=0)
        prefix_max = np.maximum.accumulate(b_end)
        # Running MBR unions (±inf empty boxes are the min/max identities):
        # prefix[j] covers bins [0, j], suffix[j] covers bins [j, m) — a
        # query range [j_lo, j_hi] is a subset of both, so the larger of
        # the two box distances lower-bounds the distance to the range's
        # true union (the whole-range quick reject in candidate_subranges).
        prefix_lo = np.minimum.accumulate(mbr_lo, axis=0)
        prefix_hi = np.maximum.accumulate(mbr_hi, axis=0)
        suffix_lo = np.minimum.accumulate(mbr_lo[::-1], axis=0)[::-1].copy()
        suffix_hi = np.maximum.accumulate(mbr_hi[::-1], axis=0)[::-1].copy()
        scale = float(max(np.abs(seg_lo).max(), np.abs(seg_hi).max(), 1.0))
        # Coarse pricing grid: chunks of fine bins unioned down to at most
        # COARSE_GRID_BINS boxes; chunk c's segment range is contiguous
        # because the fine bins partition the sorted segment array.
        chunk = max((num_bins + COARSE_GRID_BINS - 1) // COARSE_GRID_BINS, 1)
        cstarts = np.arange(0, num_bins, chunk, dtype=np.int64)
        cends = np.minimum(cstarts + chunk - 1, num_bins - 1)
        coarse_lo = np.minimum.reduceat(mbr_lo, cstarts, axis=0)
        coarse_hi = np.maximum.reduceat(mbr_hi, cstarts, axis=0)
        return TemporalBinIndex(
            t0=t0, bin_width=width, num_bins=num_bins,
            b_start=b_start, b_end=b_end, b_first=b_first, b_last=b_last,
            _bend_prefix_max=prefix_max, n_segments=n,
            mbr_lo=mbr_lo, mbr_hi=mbr_hi,
            prefix_lo=prefix_lo, prefix_hi=prefix_hi,
            suffix_lo=suffix_lo, suffix_hi=suffix_hi,
            _prune_scale=scale,
            _coarse_first=b_first[cstarts], _coarse_last=b_last[cends],
            _coarse_lo=coarse_lo, _coarse_hi=coarse_hi,
        )

    # ------------------------------------------------------------------
    def bin_of(self, t_start: float) -> int:
        """floor((t_start - t0)/b), clamped into [0, m-1] (paper's bin rule)."""
        j = int(np.floor((t_start - self.t0) / self.bin_width))
        return min(max(j, 0), self.num_bins - 1)

    def _bin_range(self, qt0: float, qt1: float) -> tuple[int, int] | None:
        """Contiguous overlapping-bin range [j_lo, j_hi], or None."""
        if qt1 < qt0:
            return None
        j_hi = int(np.floor((qt1 - self.t0) / self.bin_width))
        if j_hi < 0:
            return None
        j_hi = min(j_hi, self.num_bins - 1)
        # Earliest bin whose B_end reaches qt0: prefix-max is non-decreasing
        # so binary search is valid; prefix_max[j] >= qt0 first holds at the
        # earliest overlapping bin itself.
        j_lo = int(np.searchsorted(self._bend_prefix_max, qt0, side="left"))
        if j_lo > j_hi:
            return None
        return j_lo, j_hi

    def candidate_range(self, qt0: float, qt1: float) -> tuple[int, int]:
        """Contiguous candidate index range [first, last] for query extent
        [qt0, qt1].  Returns (0, -1) when no candidates exist.

        Overlapping bins are those with ``B_start <= qt1`` and
        ``B_end >= qt0``; the range is then
        ``[min B_first, max B_last]`` over that (contiguous) set.  The
        range is clamped into ``[0, n_segments)`` — a query outlasting the
        database extent must price (and dispatch) only real segments.
        """
        r = self._bin_range(qt0, qt1)
        if r is None:
            return (0, -1)
        j_lo, j_hi = r
        # min B_first over bins [j_lo, j_hi]: b_first is non-decreasing.
        first = max(int(self.b_first[j_lo]), 0)
        last = min(int(self.b_last[j_hi]), self.n_segments - 1)
        if last < first:
            return (0, -1)
        return first, last

    def num_candidates(self, qt0: float, qt1: float) -> int:
        first, last = self.candidate_range(qt0, qt1)
        return max(last - first + 1, 0)

    def candidate_range_batch(self, qt0: np.ndarray, qt1: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized :meth:`candidate_range` over arrays of query extents.

        Returns ``(first, last)`` int64 arrays; empty ranges are encoded as
        ``last < first`` (specifically first=0, last=-1).  This is the
        workhorse of the SETSPLIT algorithms, which evaluate ``numInts`` for
        every adjacent batch pair on every merge iteration.
        """
        qt0 = np.asarray(qt0, dtype=np.float64)
        qt1 = np.asarray(qt1, dtype=np.float64)
        j_hi = np.floor((qt1 - self.t0) / self.bin_width).astype(np.int64)
        valid = (qt1 >= qt0) & (j_hi >= 0)
        j_hi = np.clip(j_hi, 0, self.num_bins - 1)
        j_lo = np.searchsorted(self._bend_prefix_max, qt0, side="left").astype(np.int64)
        valid &= j_lo <= j_hi
        j_lo = np.minimum(j_lo, self.num_bins - 1)
        # Clamp into [0, n_segments) — same contract as candidate_range.
        first = np.maximum(self.b_first[j_lo], 0)
        last = np.minimum(self.b_last[j_hi], self.n_segments - 1)
        valid &= last >= first
        first = np.where(valid, first, 0)
        last = np.where(valid, last, -1)
        return first, last

    def num_candidates_batch(self, qt0: np.ndarray, qt1: np.ndarray) -> np.ndarray:
        first, last = self.candidate_range_batch(qt0, qt1)
        return np.maximum(last - first + 1, 0)

    def num_interactions(self, qt0: float, qt1: float, batch_size: int) -> int:
        """|Q_batch| × |E_Q| — the paper's interaction count for one batch."""
        return batch_size * self.num_candidates(qt0, qt1)

    # ------------------------------------------------------------------
    def bins_overlapping(self, qt0: float, qt1: float) -> np.ndarray:
        """Indices of bins that temporally overlap [qt0, qt1] (for tests)."""
        mask = (self.b_start <= qt1) & (self.b_end >= qt0)
        return np.nonzero(mask)[0]

    # ------------------------------------------------------------------
    # spatial pruning (PR 5)
    # ------------------------------------------------------------------
    def _limit(self, d: float, qlo: np.ndarray, qhi: np.ndarray) -> float:
        """The inflated prune threshold for one query MBR (or a stack)."""
        finite = np.isfinite(qlo) & np.isfinite(qhi)
        qscale = (float(max(np.abs(qlo[finite]).max(initial=0.0),
                            np.abs(qhi[finite]).max(initial=0.0)))
                  if finite.any() else 0.0)
        return prune_limit(d, max(self._prune_scale, qscale))

    def candidate_subranges(self, qt0: float, qt1: float,
                            qlo: np.ndarray, qhi: np.ndarray, d: float, *,
                            max_subranges: int = DEFAULT_MAX_SUBRANGES
                            ) -> list[tuple[int, int]]:
        """Spatially pruned candidate sub-ranges for one query extent.

        ``qlo``/``qhi`` is the (3,) union MBR of the query segments sharing
        the extent ``[qt0, qt1]`` (a batch); ``d`` the distance threshold.
        Returns disjoint, increasing, inclusive ``(first, last)`` segment
        index sub-ranges — the temporal ``candidate_range`` with every run
        of bins farther than the inflated threshold from the query MBR (or
        temporally dead: ``B_end < qt0``) cut out.  Exact: a pruned bin's
        box lies farther than ``d`` from the whole batch MBR, hence from
        every member query's box, hence from every member query at every
        instant — no hit can be dropped.  At most ``max_subranges`` runs
        come back (surplus runs merge across the smallest gaps), bounding
        the per-batch dispatch count.
        """
        r = self._bin_range(qt0, qt1)
        if r is None:
            return []
        j_lo, j_hi = r
        first = max(int(self.b_first[j_lo]), 0)
        last = min(int(self.b_last[j_hi]), self.n_segments - 1)
        if last < first:
            return []
        qlo = np.asarray(qlo, np.float64)
        qhi = np.asarray(qhi, np.float64)
        lim = self._limit(d, qlo, qhi)
        lim2 = lim * lim
        # Whole-range quick reject: the range's true MBR union is a subset
        # of both prefix[j_hi] and suffix[j_lo], so the larger box distance
        # lower-bounds the distance to everything in the range.
        lb2 = max(float(mbr_gap2(self.prefix_lo[j_hi], self.prefix_hi[j_hi],
                                 qlo, qhi)),
                  float(mbr_gap2(self.suffix_lo[j_lo], self.suffix_hi[j_lo],
                                 qlo, qhi)))
        if lb2 > lim2:
            return []
        bins = slice(j_lo, j_hi + 1)
        gap2 = mbr_gap2(self.mbr_lo[bins], self.mbr_hi[bins], qlo, qhi)
        keep = (gap2 <= lim2) & (self.b_end[bins] >= qt0)
        kept = np.nonzero(keep)[0]
        if kept.size == 0:
            return []
        # Runs of consecutive kept bins -> segment sub-ranges.  Adjacent
        # sub-ranges with no segments between them coalesce: a pruned bin
        # that is *empty* (or whose segments all sit left of the range)
        # separates runs in bin space but not in segment space, and
        # splitting there would fragment the plan for zero pruned work
        # (e.g. integer-aligned segment starts against a finer bin grid
        # leave every fifth bin empty).
        breaks = np.nonzero(np.diff(kept) > 1)[0]
        run_a = np.concatenate([[0], breaks + 1])
        run_b = np.concatenate([breaks, [kept.size - 1]])
        subs: list[list[int]] = []
        for a, b in zip(kept[run_a], kept[run_b]):
            f = max(int(self.b_first[j_lo + a]), first)
            l = min(int(self.b_last[j_lo + b]), last)
            if l < f:
                continue
            if subs and f <= subs[-1][1] + 1:
                subs[-1][1] = max(subs[-1][1], l)
            else:
                subs.append([f, l])
        if len(subs) > max_subranges:
            # Keep only the largest inter-run gaps as split points; merging
            # across a gap re-admits the gap's segments (exactness is
            # preserved — pruning may only shrink, never grow, the result).
            gaps = np.array([subs[i + 1][0] - subs[i][1]
                             for i in range(len(subs) - 1)])
            keep = max(int(max_subranges) - 1, 0)
            splits = (set(np.argsort(gaps)[-keep:].tolist()) if keep
                      else set())
            merged = [subs[0]]
            for i, s in enumerate(subs[1:]):
                if i in splits:
                    merged.append(s)
                else:
                    merged[-1][1] = s[1]
            subs = merged
        return [(int(f), int(l)) for f, l in subs]

    def pruned_num_candidates(self, qt0: float, qt1: float, qlo, qhi,
                              d: float) -> int:
        """Exact candidate count surviving :meth:`candidate_subranges`."""
        return sum(l - f + 1 for f, l in
                   self.candidate_subranges(qt0, qt1, qlo, qhi, d))

    def estimate_pruned_candidates_batch(self, qt0, qt1, qlo, qhi,
                                         d: float) -> np.ndarray:
        """Vectorized pruned-candidate estimate over the coarse bin grid.

        ``qt0``/``qt1`` are (n,) extents, ``qlo``/``qhi`` (n, 3) query-MBR
        stacks.  For each row, the temporal ``[first, last]`` range is
        intersected with every coarse bin's segment range and coarse bins
        whose union MBR lies beyond the inflated threshold are dropped.
        Conservative with respect to the *uncapped* sub-range split (a
        coarse union prunes no more than its fine bins; the
        ``max_subranges`` cap can re-admit gap segments the estimate
        dropped, so heavily fragmented extents may dispatch slightly more
        than priced) and exactly equal to the temporal count when nothing
        is spatially pruned — this is the pricing signal the
        SETSPLIT/GREEDYSETSPLIT merge loops consume.
        """
        qt0 = np.asarray(qt0, np.float64)
        qt1 = np.asarray(qt1, np.float64)
        qlo = np.asarray(qlo, np.float64).reshape(-1, 3)
        qhi = np.asarray(qhi, np.float64).reshape(-1, 3)
        first, last = self.candidate_range_batch(qt0, qt1)
        cf, cl = self._coarse_first, self._coarse_last
        ov = (np.minimum(last[:, None], cl[None, :])
              - np.maximum(first[:, None], cf[None, :]) + 1)
        ov = np.maximum(ov, 0)
        lim = self._limit(float(d), qlo, qhi)
        gap2 = mbr_gap2(self._coarse_lo[None], self._coarse_hi[None],
                        qlo[:, None], qhi[:, None])     # (n, k)
        return (ov * (gap2 <= lim * lim)).sum(axis=1).astype(np.int64)
