"""Temporal-bin index (paper §4).

Entry segments, sorted by non-decreasing ``t_start``, are logically divided
into ``m`` fixed-width temporal bins.  Bin ``B_j`` is fully described by
``(B_start, B_end, B_first, B_last)``:

* ``B_start[j] = t0 + j*b`` where ``b = (t_max - t0) / m``;
* ``B_end[j]   = max over segments in bin of t_end`` (−inf if empty);
* ``B_first[j]`` / ``B_last[j]``: first/last segment index with
  ``t_start`` in ``[B_start[j], B_start[j]+b)``.

For a query with temporal extent ``[qt0, qt1]`` the set of overlapping bins
is contiguous, and the candidate entry segments are exactly the contiguous
index range ``[first, last]`` — this contiguity is what makes the search a
dense streaming computation on the accelerator.

The paper finds the overlapping bins with an index-tree over bin extents in
O(log m); we use the equivalent binary search over the prefix-max of
``B_end`` (non-decreasing, hence searchable) — same complexity, no tree.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.segments import SegmentArray

DEFAULT_NUM_BINS = 10_000  # paper §7.2: "the number of entry bins ... is set to 10,000"


@dataclasses.dataclass
class TemporalBinIndex:
    """The bin description arrays + the sorted segment t arrays they index."""

    t0: float
    bin_width: float
    num_bins: int
    b_start: np.ndarray      # (m,) float64 — bin start times
    b_end: np.ndarray        # (m,) float64 — max t_end in bin, −inf if empty
    b_first: np.ndarray      # (m,) int64 — first segment index in bin
    b_last: np.ndarray       # (m,) int64 — last segment index in bin (first-1 if empty)
    _bend_prefix_max: np.ndarray  # (m,) float64 — running max of b_end
    n_segments: int

    # ------------------------------------------------------------------
    @staticmethod
    def build(db: SegmentArray, num_bins: int = DEFAULT_NUM_BINS) -> "TemporalBinIndex":
        if not db.is_sorted():
            raise ValueError("TemporalBinIndex requires segments sorted by t_start")
        n = len(db)
        if n == 0:
            raise ValueError("cannot index an empty database")
        ts = db.ts.astype(np.float64)
        te = db.te.astype(np.float64)
        t0 = float(ts[0])
        t_max = float(max(ts.max(), te.max()))
        # Degenerate all-at-one-instant databases still get one valid bin.
        width = max((t_max - t0) / num_bins, np.finfo(np.float64).tiny)

        b_start = t0 + width * np.arange(num_bins, dtype=np.float64)
        edges = t0 + width * np.arange(num_bins + 1, dtype=np.float64)
        # b_first[j] = first i with ts[i] >= edge[j]; b_last[j] = b_first[j+1]-1.
        firsts = np.searchsorted(ts, edges, side="left")
        # Segments with ts == t_max would land in bin m; clamp into the last bin
        # (paper's floor(t/b) with t = t_max edge case).
        firsts[-1] = n
        b_first = firsts[:-1].astype(np.int64)
        b_last = (firsts[1:] - 1).astype(np.int64)

        b_end = np.full(num_bins, -np.inf, dtype=np.float64)
        nonempty = b_last >= b_first
        # Per-bin max of te via reduceat over the sorted layout.
        if nonempty.any():
            starts = b_first[nonempty]
            seg_max = np.maximum.reduceat(te, starts)
            # reduceat reduces [starts[k], starts[k+1]) — but consecutive
            # non-empty bins may be separated by empty ones whose range is
            # empty; since starts are the b_first of non-empty bins and the
            # next non-empty bin's b_first equals this bin's b_last+1 (empty
            # bins in between contribute no indices), the reduction ranges
            # are exactly the bins' segment ranges, except the final range
            # runs to n which is also correct.
            b_end[nonempty] = seg_max
        prefix_max = np.maximum.accumulate(b_end)
        return TemporalBinIndex(
            t0=t0, bin_width=width, num_bins=num_bins,
            b_start=b_start, b_end=b_end, b_first=b_first, b_last=b_last,
            _bend_prefix_max=prefix_max, n_segments=n,
        )

    # ------------------------------------------------------------------
    def bin_of(self, t_start: float) -> int:
        """floor((t_start - t0)/b), clamped into [0, m-1] (paper's bin rule)."""
        j = int(np.floor((t_start - self.t0) / self.bin_width))
        return min(max(j, 0), self.num_bins - 1)

    def candidate_range(self, qt0: float, qt1: float) -> tuple[int, int]:
        """Contiguous candidate index range [first, last] for query extent
        [qt0, qt1].  Returns (0, -1) when no candidates exist.

        Overlapping bins are those with ``B_start <= qt1`` and
        ``B_end >= qt0``; the range is then
        ``[min B_first, max B_last]`` over that (contiguous) set.
        """
        if qt1 < qt0:
            return (0, -1)
        j_hi = int(np.floor((qt1 - self.t0) / self.bin_width))
        if j_hi < 0:
            return (0, -1)
        j_hi = min(j_hi, self.num_bins - 1)
        # Earliest bin whose B_end reaches qt0: prefix-max is non-decreasing
        # so binary search is valid; prefix_max[j] >= qt0 first holds at the
        # earliest overlapping bin itself.
        j_lo = int(np.searchsorted(self._bend_prefix_max, qt0, side="left"))
        if j_lo > j_hi:
            return (0, -1)
        # min B_first over bins [j_lo, j_hi]: b_first is non-decreasing.
        first = int(self.b_first[j_lo])
        last = int(self.b_last[j_hi])
        if last < first:
            return (0, -1)
        return first, last

    def num_candidates(self, qt0: float, qt1: float) -> int:
        first, last = self.candidate_range(qt0, qt1)
        return max(last - first + 1, 0)

    def candidate_range_batch(self, qt0: np.ndarray, qt1: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized :meth:`candidate_range` over arrays of query extents.

        Returns ``(first, last)`` int64 arrays; empty ranges are encoded as
        ``last < first`` (specifically first=0, last=-1).  This is the
        workhorse of the SETSPLIT algorithms, which evaluate ``numInts`` for
        every adjacent batch pair on every merge iteration.
        """
        qt0 = np.asarray(qt0, dtype=np.float64)
        qt1 = np.asarray(qt1, dtype=np.float64)
        j_hi = np.floor((qt1 - self.t0) / self.bin_width).astype(np.int64)
        valid = (qt1 >= qt0) & (j_hi >= 0)
        j_hi = np.clip(j_hi, 0, self.num_bins - 1)
        j_lo = np.searchsorted(self._bend_prefix_max, qt0, side="left").astype(np.int64)
        valid &= j_lo <= j_hi
        j_lo = np.minimum(j_lo, self.num_bins - 1)
        first = self.b_first[j_lo]
        last = self.b_last[j_hi]
        valid &= last >= first
        first = np.where(valid, first, 0)
        last = np.where(valid, last, -1)
        return first, last

    def num_candidates_batch(self, qt0: np.ndarray, qt1: np.ndarray) -> np.ndarray:
        first, last = self.candidate_range_batch(qt0, qt1)
        return np.maximum(last - first + 1, 0)

    def num_interactions(self, qt0: float, qt1: float, batch_size: int) -> int:
        """|Q_batch| × |E_Q| — the paper's interaction count for one batch."""
        return batch_size * self.num_candidates(qt0, qt1)

    # ------------------------------------------------------------------
    def bins_overlapping(self, qt0: float, qt1: float) -> np.ndarray:
        """Indices of bins that temporally overlap [qt0, qt1] (for tests)."""
        mask = (self.b_start <= qt1) & (self.b_end >= qt0)
        return np.nonzero(mask)[0]
