"""End-to-end distance-threshold query engine (paper §4–§5).

Pipeline per the paper's "general approach" (§4): the sorted entry segments
live on the device once and for all; the host keeps the temporal-bin index
and the sorted query set; queries are partitioned into batches (see
``repro.core.batching``); for each batch the host computes the contiguous
candidate index range from the bins and dispatches one device computation
comparing the batch's query segments against that candidate slice.

PR 3 split this module's former responsibilities three ways:

* **planning** (batching algorithm, capacity sizing, dispatch grouping)
  lives in ``repro.core.planner`` — the engine consumes a ``QueryPlan``
  (legacy ``BatchPlan`` arguments are coerced via ``as_query_plan``);
* **execution** (the per-batch sync loop and the two-phase pipelined
  dispatch with its overflow-retry protocol) lives in
  ``repro.core.executor`` — shared with the sharded mesh backend
  (``repro.core.distributed.ShardedEngine``);
* this module keeps the **single-device dispatcher**: slicing the packed
  segment arrays, the async ``ops.query_block`` dispatch, and host-side
  result marshalling — plus the public ``DistanceThresholdEngine`` shell.

TPU adaptations on top of the paper (see the executor/planner modules for
the mechanics):

* **Shape bucketing.**  The GPU pays a per-invocation overhead Θ; the XLA
  analogue is *compilation* of every new shape.  Result capacities round up
  to power-of-two buckets (``planner.bucket_capacity``) so the jit cache
  stays O(log²) instead of O(batches).
* **Overflow-retry result buffers.**  The paper statically allocates |D|
  result slots (§5).  We allocate ``capacity`` slots per batch and retry
  with doubled (bucketed) capacity on overflow — the kernel always reports
  the *exact* hit count, so a retry converges after a single re-dispatch.
* **Async pipelined execution** (``pipeline=True``, the default): ≤ 2 host
  syncs per dispatch group (one group per query set by default) instead of
  one per batch, with host marshalling of group k overlapped with device
  compute of group k+1.  ``pipeline=False`` keeps the classic per-batch
  sync loop (used by the §8 perf-model fits, which need per-invocation
  timings — see ``BatchStats``).
* **Deterministic output.**  Results are emitted in a deterministic
  per-batch order (row-major for dense compaction; tile-then-row-major for
  fused — see ``repro.kernels.ops``), concatenated in batch order.
"""
from __future__ import annotations

import numpy as np

from repro import faults
from repro.core.batching import BatchPlan
from repro.core.executor import (BatchStats, Dispatch,  # noqa: F401 (stable re-exports)
                                 ExecStats, ResultSet, make_executor)
from repro.core.index import DEFAULT_NUM_BINS, TemporalBinIndex
from repro.core.planner import (QueryPlan, as_query_plan,
                                bucket_capacity as _bucket)
from repro.core.segments import SegmentArray
from repro.kernels import ops
from repro.kernels.distthresh import DEFAULT_CAND_BLK, DEFAULT_QRY_BLK


class _QueryBlockDispatcher:
    """Single-device dispatcher: contiguous host slices → ``query_block``.

    Implements the ``repro.core.executor.BatchDispatcher`` protocol for one
    (engine, query set, threshold) binding.
    """

    def __init__(self, engine: "DistanceThresholdEngine",
                 q_packed: np.ndarray, d: float):
        self.engine = engine
        self.q_packed = q_packed
        self.d = d

    def dispatch(self, batch, capacity: int) -> Dispatch:
        eng = self.engine
        if faults.armed():
            faults.inject("engine.dispatch", q_first=int(batch.q_first),
                          use_pallas=eng.use_pallas,
                          compaction=eng.compaction)
        # Hierarchical pruning plans box-level sub-ranges in the index's
        # *permuted* segment order, so the dispatched slices come from the
        # permuted packed copy (identical to ``_packed`` when K=1).
        packed = (eng._packed_perm if eng.pruning == "hierarchical"
                  else eng._packed)
        e_slice = packed[batch.cand_first:batch.cand_last + 1]
        q_slice = self.q_packed[batch.q_first:batch.q_last + 1]
        out = ops.query_block(
            e_slice, q_slice, np.float32(self.d), capacity=capacity,
            use_pallas=eng.use_pallas, interpret=eng.interpret,
            cand_blk=eng.cand_blk, qry_blk=eng.qry_blk,
            compaction=eng.compaction, pruning=eng.pruning)
        return Dispatch(batch, capacity, out)

    def count(self, dp: Dispatch) -> int:
        count = int(dp.out["count"])
        if faults.armed():
            count = faults.corrupt("engine.count", count,
                                   q_first=int(dp.batch.q_first))
        return count

    def tile_stats(self, dp: Dispatch) -> tuple[int, int]:
        """Kernel-level pruning counters (executor hook; see
        ``repro.core.executor._tile_stats``)."""
        return int(dp.out["pruned_tiles"]), int(dp.out["num_tiles"])

    def retry_capacity(self, dp: Dispatch) -> int | None:
        count = self.count(dp)
        return _bucket(count) if count > dp.capacity else None

    def marshal(self, dp: Dispatch, count: int) -> ResultSet | None:
        if faults.armed():
            faults.inject("engine.marshal", q_first=int(dp.batch.q_first))
        batch, out, db = dp.batch, dp.out, self.engine.db
        # Mask on the buffer's -1 pads (every kernel variant initializes the
        # index buffers to -1) instead of trusting ``count``: a corrupted
        # overflow count then costs at most one spurious bounded retry — it
        # can never leak pad rows into results nor drop real ones.
        e_buf = np.asarray(out["entry_idx"])
        keep = e_buf >= 0
        if not keep.any():
            return None
        e_local = e_buf[keep]
        q_local = np.asarray(out["query_idx"])[keep]
        e_global = batch.cand_first + e_local.astype(np.int64)
        if self.engine.pruning == "hierarchical":
            perm = self.engine.index.perm
            if perm is not None:
                # Permuted dispatch position → original sorted-db index, so
                # results stay byte-identical across pruning modes.
                e_global = perm[e_global]
        return ResultSet(
            entry_idx=e_global,
            entry_traj=db.traj_id[e_global].astype(np.int64),
            entry_seg=db.seg_id[e_global].astype(np.int64),
            query_idx=batch.q_first + q_local.astype(np.int64),
            t_enter=np.asarray(out["t_enter"])[keep],
            t_exit=np.asarray(out["t_exit"])[keep],
        )


class DistanceThresholdEngine:
    """In-memory distance-threshold query engine over a trajectory database."""

    def __init__(self, db: SegmentArray, *, num_bins: int = DEFAULT_NUM_BINS,
                 use_pallas: bool = False, interpret: bool = True,
                 cand_blk: int = DEFAULT_CAND_BLK, qry_blk: int = DEFAULT_QRY_BLK,
                 default_capacity: int = 4096, compaction: str = "fused",
                 pipeline: bool = True, pruning: str = "spatial",
                 index_kboxes: int = 1, max_capacity_retries: int = 3):
        """``use_pallas=False`` routes interactions through the jnp oracle —
        the right default on CPU where Pallas runs in interpret mode.  Both
        paths share identical semantics (tests assert equality).

        ``compaction`` selects the result-compaction strategy ("fused" uses
        the in-kernel compaction kernel on the Pallas path, falling back to
        "fused_rowloop" if the gather path fails to lower — see
        ``repro.kernels.ops``; "dense" forces the two-phase fallback; the
        jnp oracle is always dense).  ``pipeline`` selects the async
        two-phase executor (see the module docstring); both can be
        overridden per call on :meth:`execute`.

        ``pruning="spatial"`` (the default) arms the fused kernels'
        tile-level MBR early-out (work-only — the result set is provably
        unchanged); the planner-level candidate trimming lives upstream in
        ``repro.core.planner`` and reaches this engine through the plan.
        ``pruning="hierarchical"`` plans against the K-box-per-bin level
        and dispatches with the live-tile kernel; its plans address the
        index's *permuted* segment order, so plan and engine must agree on
        the pruning mode (the facade guarantees it; direct engine users
        own that consistency).  ``index_kboxes`` is the per-bin spatial
        split factor K handed to ``TemporalBinIndex.build`` — structural
        (the default K=1 makes hierarchical planning degenerate to
        bin-level boxes while keeping the live-tile kernel dispatch).
        """
        if compaction not in ops.COMPACTIONS:
            raise ValueError(f"unknown compaction {compaction!r}; "
                             f"choose from {ops.COMPACTIONS}")
        if pruning not in ops.PRUNINGS:
            raise ValueError(f"unknown pruning {pruning!r}; "
                             f"choose from {ops.PRUNINGS}")
        self.db = db if db.is_sorted() else db.sort_by_tstart()
        self.index = TemporalBinIndex.build(self.db, num_bins,
                                            kboxes=index_kboxes)
        self._packed = self.db.packed()          # (n, 8) float32, host copy
        # Permuted device layout for hierarchical (box-level) plans: row i
        # holds the segment at sorted-db position perm[i].  Alias when K=1.
        self._packed_perm = (self._packed if self.index.perm is None
                             else self._packed[self.index.perm])
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.cand_blk = cand_blk
        self.qry_blk = qry_blk
        self.default_capacity = default_capacity
        self.compaction = compaction
        self.pipeline = pipeline
        self.pruning = pruning
        # Bounded overflow-retry (PR 10): batches whose hits still exceed
        # capacity after this many doublings raise CapacityError.
        self.max_capacity_retries = int(max_capacity_retries)

    # ------------------------------------------------------------------
    def dispatcher(self, queries_packed: np.ndarray,
                   d: float) -> _QueryBlockDispatcher:
        """The engine's ``BatchDispatcher`` for one query set (executor
        protocol interop — the scheduler and tests drive it directly)."""
        return _QueryBlockDispatcher(self, queries_packed, float(d))

    # ------------------------------------------------------------------
    def execute(self, queries: SegmentArray, d: float,
                plan: BatchPlan | QueryPlan,
                *, pipeline: bool | None = None,
                on_group=None) -> tuple[ResultSet, ExecStats]:
        """Run every batch in ``plan`` against the database.

        ``plan`` may be a refined ``QueryPlan`` (the facade's planner
        output, carrying capacities + dispatch groups) or a legacy
        ``BatchPlan`` (coerced to a single-group plan sized by the engine's
        ``default_capacity``).  ``pipeline`` overrides the engine-level
        default for this call (``None`` → use ``self.pipeline``);
        ``on_group`` is the executor's group-completion hook (incremental
        result delivery — see ``repro.core.executor.GroupHook``).
        """
        if not queries.is_sorted():
            # Unreachable from the public facade: repro.api.TrajectoryDB
            # sorts queries before planning/execution.  Kept as a guard for
            # direct engine users, who own the sortedness precondition.
            raise ValueError(
                "queries must be sorted by t_start; use "
                "repro.api.TrajectoryDB.query, which sorts automatically")
        qplan = as_query_plan(plan, default_capacity=self.default_capacity)
        use_pipeline = self.pipeline if pipeline is None else pipeline
        executor = make_executor(self.dispatcher(queries.packed(), d),
                                 pipeline=use_pipeline, on_group=on_group,
                                 max_capacity_retries=getattr(
                                     self, "max_capacity_retries", 3))
        return executor.run(qplan)


# ----------------------------------------------------------------------
# Brute-force oracle (for tests): all pairs, no index, chunked.
# ----------------------------------------------------------------------
def brute_force(db: SegmentArray, queries: SegmentArray, d: float,
                chunk: int = 2048) -> ResultSet:  # lint: ignore[SYNC001] — synchronous oracle; per-chunk host reads are its contract, not a pipeline leak
    """All-pairs reference: compares every entry to every query segment."""
    db_packed = db.packed()
    q_packed = queries.packed()
    parts: list[ResultSet] = []
    for c0 in range(0, len(db), chunk):
        e_slice = db_packed[c0:c0 + chunk]
        t_enter, t_exit, hit = ops.interaction_tiles(
            e_slice, q_packed, np.float32(d), use_pallas=False)
        hit = np.asarray(hit)
        if not hit.any():
            continue
        ei, qi = np.nonzero(hit)
        eg = c0 + ei.astype(np.int64)
        parts.append(ResultSet(
            entry_idx=eg,
            entry_traj=db.traj_id[eg].astype(np.int64),
            entry_seg=db.seg_id[eg].astype(np.int64),
            query_idx=qi.astype(np.int64),
            t_enter=np.asarray(t_enter)[ei, qi],
            t_exit=np.asarray(t_exit)[ei, qi],
        ))
    return ResultSet.concatenate(parts).sorted_canonical()
