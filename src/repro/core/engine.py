"""End-to-end distance-threshold query engine (paper §4–§5).

Pipeline per the paper's "general approach" (§4): the sorted entry segments
live on the device once and for all; the host keeps the temporal-bin index
and the sorted query set; queries are partitioned into batches (see
``repro.core.batching``); for each batch the host computes the contiguous
candidate index range from the bins and dispatches one device computation
comparing the batch's query segments against that candidate slice.

TPU adaptations on top of the paper:

* **Shape bucketing.**  The GPU pays a per-invocation overhead Θ; the XLA
  analogue is *compilation* of every new (C, Q) shape.  We round candidate
  and query counts up to power-of-two buckets (multiples of the kernel tile)
  so the jit cache stays O(log²) instead of O(batches).  Padded rows have
  temporal extents outside the data range and can never hit.
* **Overflow-retry result buffers.**  The paper statically allocates |D|
  result slots (§5).  We allocate ``capacity`` slots per batch and retry
  with doubled capacity on overflow — the paper's own suggested refinement.
* **Deterministic output.**  Results are emitted in (entry, query) row-major
  order per batch, concatenated in batch order.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.batching import BatchPlan
from repro.core.index import DEFAULT_NUM_BINS, TemporalBinIndex
from repro.core.segments import SegmentArray
from repro.kernels import ops
from repro.kernels.distthresh import DEFAULT_CAND_BLK, DEFAULT_QRY_BLK


@dataclasses.dataclass
class ResultSet:
    """Flat result arrays: one row per (entry segment, query segment, interval)."""

    entry_idx: np.ndarray    # global index into the sorted database
    entry_traj: np.ndarray   # trajectory id of the entry segment
    entry_seg: np.ndarray    # segment id of the entry segment
    query_idx: np.ndarray    # global index into the sorted query array
    t_enter: np.ndarray
    t_exit: np.ndarray

    def __len__(self) -> int:
        return int(self.entry_idx.shape[0])

    @staticmethod
    def empty() -> "ResultSet":
        zi = np.zeros(0, np.int64)
        zf = np.zeros(0, np.float32)
        return ResultSet(zi, zi.copy(), zi.copy(), zi.copy(), zf, zf.copy())

    @staticmethod
    def concatenate(parts: list["ResultSet"]) -> "ResultSet":
        if not parts:
            return ResultSet.empty()
        return ResultSet(*[np.concatenate([getattr(p, f.name) for p in parts])
                           for f in dataclasses.fields(ResultSet)])

    def sorted_canonical(self) -> "ResultSet":
        """Canonical (entry_idx, query_idx) order — for set comparisons."""
        order = np.lexsort((self.query_idx, self.entry_idx))
        return ResultSet(*[getattr(self, f.name)[order]
                           for f in dataclasses.fields(ResultSet)])


@dataclasses.dataclass
class BatchStats:
    """Per-invocation record (feeds the §8 performance model)."""

    batch_size: int
    num_candidates: int
    num_interactions: int
    num_hits: int
    kernel_seconds: float
    retries: int


@dataclasses.dataclass
class ExecStats:
    plan_seconds: float
    total_seconds: float
    batches: list[BatchStats]

    @property
    def kernel_seconds(self) -> float:
        return sum(b.kernel_seconds for b in self.batches)

    @property
    def host_seconds(self) -> float:
        return self.total_seconds - self.kernel_seconds

    @property
    def total_interactions(self) -> int:
        return sum(b.num_interactions for b in self.batches)

    @property
    def total_hits(self) -> int:
        return sum(b.num_hits for b in self.batches)

    @property
    def num_invocations(self) -> int:
        return len(self.batches)


def _bucket(n: int, blk: int) -> int:
    """Round up to blk, then to blk·2^k — bounds the jit-cache size."""
    n = max(n, 1)
    b = blk
    while b < n:
        b *= 2
    return b


class DistanceThresholdEngine:
    """In-memory distance-threshold query engine over a trajectory database."""

    def __init__(self, db: SegmentArray, *, num_bins: int = DEFAULT_NUM_BINS,
                 use_pallas: bool = False, interpret: bool = True,
                 cand_blk: int = DEFAULT_CAND_BLK, qry_blk: int = DEFAULT_QRY_BLK,
                 default_capacity: int = 4096):
        """``use_pallas=False`` routes interactions through the jnp oracle —
        the right default on CPU where Pallas runs in interpret mode.  Both
        paths share identical semantics (tests assert equality)."""
        self.db = db if db.is_sorted() else db.sort_by_tstart()
        self.index = TemporalBinIndex.build(self.db, num_bins)
        self._packed = self.db.packed()          # (n, 8) float32, host copy
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.cand_blk = cand_blk
        self.qry_blk = qry_blk
        self.default_capacity = default_capacity

    # ------------------------------------------------------------------
    def execute(self, queries: SegmentArray, d: float,
                plan: BatchPlan) -> tuple[ResultSet, ExecStats]:
        """Run every batch in ``plan`` against the database."""
        if not queries.is_sorted():
            # Unreachable from the public facade: repro.api.TrajectoryDB
            # sorts queries before planning/execution.  Kept as a guard for
            # direct engine users, who own the sortedness precondition.
            raise ValueError(
                "queries must be sorted by t_start; use "
                "repro.api.TrajectoryDB.query, which sorts automatically")
        q_packed = queries.packed()
        t_begin = time.perf_counter()
        parts: list[ResultSet] = []
        stats: list[BatchStats] = []
        for batch in plan.batches:
            n_cand = batch.num_candidates
            bs = batch.size
            if n_cand == 0:
                stats.append(BatchStats(bs, 0, 0, 0, 0.0, 0))
                continue
            e_slice = self._packed[batch.cand_first:batch.cand_last + 1]
            q_slice = q_packed[batch.q_first:batch.q_last + 1]
            capacity = _bucket(min(self.default_capacity, n_cand * bs), 256)
            t0 = time.perf_counter()
            retries = 0
            while True:
                out = ops.query_block(
                    e_slice, q_slice, np.float32(d), capacity=capacity,
                    use_pallas=self.use_pallas, interpret=self.interpret,
                    cand_blk=self.cand_blk, qry_blk=self.qry_blk)
                count = int(out["count"])
                if count <= capacity:
                    break
                capacity = _bucket(count, 256)     # §5 re-attempt path
                retries += 1
            kernel_s = time.perf_counter() - t0
            if count > 0:
                e_local = np.asarray(out["entry_idx"][:count])
                q_local = np.asarray(out["query_idx"][:count])
                e_global = batch.cand_first + e_local.astype(np.int64)
                parts.append(ResultSet(
                    entry_idx=e_global,
                    entry_traj=self.db.traj_id[e_global].astype(np.int64),
                    entry_seg=self.db.seg_id[e_global].astype(np.int64),
                    query_idx=batch.q_first + q_local.astype(np.int64),
                    t_enter=np.asarray(out["t_enter"][:count]),
                    t_exit=np.asarray(out["t_exit"][:count]),
                ))
            stats.append(BatchStats(bs, n_cand, bs * n_cand, count,
                                    kernel_s, retries))
        total = time.perf_counter() - t_begin
        return (ResultSet.concatenate(parts),
                ExecStats(plan.plan_seconds, total, stats))


# ----------------------------------------------------------------------
# Brute-force oracle (for tests): all pairs, no index, chunked.
# ----------------------------------------------------------------------
def brute_force(db: SegmentArray, queries: SegmentArray, d: float,
                chunk: int = 2048) -> ResultSet:
    """All-pairs reference: compares every entry to every query segment."""
    db_packed = db.packed()
    q_packed = queries.packed()
    parts: list[ResultSet] = []
    for c0 in range(0, len(db), chunk):
        e_slice = db_packed[c0:c0 + chunk]
        t_enter, t_exit, hit = ops.interaction_tiles(
            e_slice, q_packed, np.float32(d), use_pallas=False)
        hit = np.asarray(hit)
        if not hit.any():
            continue
        ei, qi = np.nonzero(hit)
        eg = c0 + ei.astype(np.int64)
        parts.append(ResultSet(
            entry_idx=eg,
            entry_traj=db.traj_id[eg].astype(np.int64),
            entry_seg=db.seg_id[eg].astype(np.int64),
            query_idx=qi.astype(np.int64),
            t_enter=np.asarray(t_enter)[ei, qi],
            t_exit=np.asarray(t_exit)[ei, qi],
        ))
    return ResultSet.concatenate(parts).sorted_canonical()
