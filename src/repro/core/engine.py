"""End-to-end distance-threshold query engine (paper §4–§5).

Pipeline per the paper's "general approach" (§4): the sorted entry segments
live on the device once and for all; the host keeps the temporal-bin index
and the sorted query set; queries are partitioned into batches (see
``repro.core.batching``); for each batch the host computes the contiguous
candidate index range from the bins and dispatches one device computation
comparing the batch's query segments against that candidate slice.

TPU adaptations on top of the paper:

* **Shape bucketing.**  The GPU pays a per-invocation overhead Θ; the XLA
  analogue is *compilation* of every new (C, Q) shape.  We round candidate
  and query counts up to power-of-two buckets (multiples of the kernel tile)
  so the jit cache stays O(log²) instead of O(batches).  Padded rows have
  temporal extents outside the data range and can never hit.
* **Overflow-retry result buffers.**  The paper statically allocates |D|
  result slots (§5).  We allocate ``capacity`` slots per batch and retry
  with doubled (power-of-two bucketed) capacity on overflow — the paper's
  own suggested refinement.  The kernel always reports the *exact* hit
  count, so a retry sizes its buffer in one jump and converges after a
  single re-dispatch.
* **Async pipelined execution** (``pipeline=True``, the default).  The
  paper's host blocks on every kernel invocation; the XLA analogue of that
  serialization is a host sync (device read) per batch.  The pipelined
  executor instead runs two phases: phase A dispatches *every* batch's
  ``query_block`` back-to-back — JAX async dispatch queues them on the
  device while the host keeps planning/slicing — and phase B performs one
  ``block_until_ready`` over all outputs, reads every count, re-dispatches
  only the overflowed batches at enlarged capacity, and syncs once more.
  Host round-trips per query set drop from O(num_batches) to O(1)
  (``ExecStats.num_syncs`` ≤ 2), and device work overlaps host batch
  bookkeeping.  ``pipeline=False`` keeps the classic per-batch sync loop
  (used by the §8 perf-model fits, which need per-invocation timings).
* **Deterministic output.**  Results are emitted in a deterministic
  per-batch order (row-major for dense compaction; tile-then-row-major for
  fused — see ``repro.kernels.ops``), concatenated in batch order.

Timing discipline (feeds ``repro.core.perfmodel``): in sync mode
``BatchStats.kernel_seconds`` measures dispatch + device time of the first
invocation only, via ``jax.block_until_ready``; overflow re-dispatch wall
time is recorded separately in ``BatchStats.retry_seconds``.  Host-side
result marshalling is never charged to kernel time.  In pipelined mode
per-batch device time is unobservable without per-batch syncs (the point is
not to have them), so batches carry zero kernel time and the aggregate
device wait is in ``ExecStats.sync_seconds``.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core.batching import BatchPlan
from repro.core.index import DEFAULT_NUM_BINS, TemporalBinIndex
from repro.core.segments import SegmentArray
from repro.kernels import ops
from repro.kernels.distthresh import DEFAULT_CAND_BLK, DEFAULT_QRY_BLK


@dataclasses.dataclass
class ResultSet:
    """Flat result arrays: one row per (entry segment, query segment, interval)."""

    entry_idx: np.ndarray    # global index into the sorted database
    entry_traj: np.ndarray   # trajectory id of the entry segment
    entry_seg: np.ndarray    # segment id of the entry segment
    query_idx: np.ndarray    # global index into the sorted query array
    t_enter: np.ndarray
    t_exit: np.ndarray

    def __len__(self) -> int:
        return int(self.entry_idx.shape[0])

    @staticmethod
    def empty() -> "ResultSet":
        zi = np.zeros(0, np.int64)
        zf = np.zeros(0, np.float32)
        return ResultSet(zi, zi.copy(), zi.copy(), zi.copy(), zf, zf.copy())

    @staticmethod
    def concatenate(parts: list["ResultSet"]) -> "ResultSet":
        if not parts:
            return ResultSet.empty()
        return ResultSet(*[np.concatenate([getattr(p, f.name) for p in parts])
                           for f in dataclasses.fields(ResultSet)])

    def sorted_canonical(self) -> "ResultSet":
        """Canonical (entry_idx, query_idx) order — for set comparisons."""
        order = np.lexsort((self.query_idx, self.entry_idx))
        return ResultSet(*[getattr(self, f.name)[order]
                           for f in dataclasses.fields(ResultSet)])


@dataclasses.dataclass
class BatchStats:
    """Per-invocation record (feeds the §8 performance model).

    ``kernel_seconds`` is dispatch + device time of the batch's first
    invocation (timed with ``block_until_ready``); ``retry_seconds`` is the
    wall time of overflow re-dispatches, kept separate so perf-model fits
    see clean per-invocation numbers.  Pipelined execution reports both as
    zero per batch (see ``ExecStats.sync_seconds``).
    """

    batch_size: int
    num_candidates: int
    num_interactions: int
    num_hits: int
    kernel_seconds: float
    retries: int
    retry_seconds: float = 0.0


@dataclasses.dataclass
class ExecStats:
    plan_seconds: float
    total_seconds: float
    batches: list[BatchStats]
    #: host↔device synchronization points (count reads / block_until_ready):
    #: one per invocation (+retries) in sync mode; ≤ 2 per query set in
    #: pipelined mode — the headline O(1)-sync property.
    num_syncs: int = 0
    #: pipelined mode only: wall time of phase A (async dispatch of every
    #: batch) and of the phase B device waits.
    dispatch_seconds: float = 0.0
    sync_seconds: float = 0.0
    pipelined: bool = False

    @property
    def kernel_seconds(self) -> float:
        """First-dispatch device time (+ the pipelined device wait) — retry
        re-dispatch time is deliberately excluded so perf-model fits see
        per-invocation numbers; it is accounted in :attr:`retry_seconds`."""
        return sum(b.kernel_seconds for b in self.batches) + self.sync_seconds

    @property
    def retry_seconds(self) -> float:
        return sum(b.retry_seconds for b in self.batches)

    @property
    def host_seconds(self) -> float:
        """Wall time not spent on device work: retries are device time too,
        so they are subtracted alongside kernel_seconds."""
        return self.total_seconds - self.kernel_seconds - self.retry_seconds

    @property
    def total_interactions(self) -> int:
        return sum(b.num_interactions for b in self.batches)

    @property
    def total_hits(self) -> int:
        return sum(b.num_hits for b in self.batches)

    @property
    def num_invocations(self) -> int:
        return len(self.batches)

    @property
    def total_retries(self) -> int:
        return sum(b.retries for b in self.batches)


def _bucket(n: int, blk: int) -> int:
    """Round up to blk, then to blk·2^k — bounds the jit-cache size."""
    n = max(n, 1)
    b = blk
    while b < n:
        b *= 2
    return b


class DistanceThresholdEngine:
    """In-memory distance-threshold query engine over a trajectory database."""

    def __init__(self, db: SegmentArray, *, num_bins: int = DEFAULT_NUM_BINS,
                 use_pallas: bool = False, interpret: bool = True,
                 cand_blk: int = DEFAULT_CAND_BLK, qry_blk: int = DEFAULT_QRY_BLK,
                 default_capacity: int = 4096, compaction: str = "fused",
                 pipeline: bool = True):
        """``use_pallas=False`` routes interactions through the jnp oracle —
        the right default on CPU where Pallas runs in interpret mode.  Both
        paths share identical semantics (tests assert equality).

        ``compaction`` selects the result-compaction strategy ("fused" uses
        the in-kernel compaction kernel on the Pallas path; "dense" forces
        the two-phase fallback; the jnp oracle is always dense).
        ``pipeline`` selects the async two-phase executor (see the module
        docstring); both can be overridden per call on :meth:`execute`.
        """
        if compaction not in ops.COMPACTIONS:
            raise ValueError(f"unknown compaction {compaction!r}; "
                             f"choose from {ops.COMPACTIONS}")
        self.db = db if db.is_sorted() else db.sort_by_tstart()
        self.index = TemporalBinIndex.build(self.db, num_bins)
        self._packed = self.db.packed()          # (n, 8) float32, host copy
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.cand_blk = cand_blk
        self.qry_blk = qry_blk
        self.default_capacity = default_capacity
        self.compaction = compaction
        self.pipeline = pipeline

    # ------------------------------------------------------------------
    def _dispatch(self, e_slice, q_slice, d, capacity: int):
        """One async ``query_block`` dispatch (no host sync)."""
        return ops.query_block(
            e_slice, q_slice, np.float32(d), capacity=capacity,
            use_pallas=self.use_pallas, interpret=self.interpret,
            cand_blk=self.cand_blk, qry_blk=self.qry_blk,
            compaction=self.compaction)

    def _slices(self, batch, q_packed):
        e_slice = self._packed[batch.cand_first:batch.cand_last + 1]
        q_slice = q_packed[batch.q_first:batch.q_last + 1]
        capacity = _bucket(min(self.default_capacity,
                               batch.num_candidates * batch.size), 256)
        return e_slice, q_slice, capacity

    def _batch_part(self, batch, out, count: int) -> ResultSet | None:
        """Marshal one batch's device buffers into a host ResultSet part."""
        if count == 0:
            return None
        e_local = np.asarray(out["entry_idx"][:count])
        q_local = np.asarray(out["query_idx"][:count])
        e_global = batch.cand_first + e_local.astype(np.int64)
        return ResultSet(
            entry_idx=e_global,
            entry_traj=self.db.traj_id[e_global].astype(np.int64),
            entry_seg=self.db.seg_id[e_global].astype(np.int64),
            query_idx=batch.q_first + q_local.astype(np.int64),
            t_enter=np.asarray(out["t_enter"][:count]),
            t_exit=np.asarray(out["t_exit"][:count]),
        )

    # ------------------------------------------------------------------
    def execute(self, queries: SegmentArray, d: float, plan: BatchPlan,
                *, pipeline: bool | None = None) -> tuple[ResultSet, ExecStats]:
        """Run every batch in ``plan`` against the database.

        ``pipeline`` overrides the engine-level default for this call
        (``None`` → use ``self.pipeline``).
        """
        if not queries.is_sorted():
            # Unreachable from the public facade: repro.api.TrajectoryDB
            # sorts queries before planning/execution.  Kept as a guard for
            # direct engine users, who own the sortedness precondition.
            raise ValueError(
                "queries must be sorted by t_start; use "
                "repro.api.TrajectoryDB.query, which sorts automatically")
        q_packed = queries.packed()
        use_pipeline = self.pipeline if pipeline is None else pipeline
        if use_pipeline:
            return self._execute_pipelined(q_packed, d, plan)
        return self._execute_sync(q_packed, d, plan)

    # ------------------------------------------------------------------
    def _execute_sync(self, q_packed, d: float,
                      plan: BatchPlan) -> tuple[ResultSet, ExecStats]:
        """Classic per-batch loop: dispatch → sync → (maybe retry) → next."""
        t_begin = time.perf_counter()
        parts: list[ResultSet] = []
        stats: list[BatchStats] = []
        num_syncs = 0
        for batch in plan.batches:
            n_cand = batch.num_candidates
            bs = batch.size
            if n_cand == 0:
                stats.append(BatchStats(bs, 0, 0, 0, 0.0, 0))
                continue
            e_slice, q_slice, capacity = self._slices(batch, q_packed)
            t0 = time.perf_counter()
            out = self._dispatch(e_slice, q_slice, d, capacity)
            jax.block_until_ready(out)
            kernel_s = time.perf_counter() - t0
            num_syncs += 1
            count = int(out["count"])
            retries = 0
            retry_s = 0.0
            while count > capacity:                    # §5 re-attempt path
                capacity = _bucket(count, 256)
                t0r = time.perf_counter()
                out = self._dispatch(e_slice, q_slice, d, capacity)
                jax.block_until_ready(out)
                retry_s += time.perf_counter() - t0r
                num_syncs += 1
                count = int(out["count"])
                retries += 1
            part = self._batch_part(batch, out, count)
            if part is not None:
                parts.append(part)
            stats.append(BatchStats(bs, n_cand, bs * n_cand, count,
                                    kernel_s, retries, retry_s))
        total = time.perf_counter() - t_begin
        return (ResultSet.concatenate(parts),
                ExecStats(plan.plan_seconds, total, stats,
                          num_syncs=num_syncs, pipelined=False))

    # ------------------------------------------------------------------
    def _execute_pipelined(self, q_packed, d: float,
                           plan: BatchPlan) -> tuple[ResultSet, ExecStats]:
        """Two-phase executor: dispatch everything, then sync once.

        Phase A queues every batch's kernel via JAX async dispatch — no
        device reads, so the host never stalls between batches.  Phase B
        blocks once on all outputs, reads every exact count, re-dispatches
        only the overflowed batches at enlarged (≥ doubled) capacity, and
        syncs those once more: ≤ 2 host syncs per query set total.
        """
        t_begin = time.perf_counter()
        # Phase A: async dispatch of every non-empty batch.
        inflight: list[tuple[int, object, object, object]] = []
        order: list[tuple[object, int, int]] = []   # (batch, n_cand, slot)
        for batch in plan.batches:
            n_cand = batch.num_candidates
            if n_cand == 0:
                order.append((batch, 0, -1))
                continue
            e_slice, q_slice, capacity = self._slices(batch, q_packed)
            out = self._dispatch(e_slice, q_slice, d, capacity)
            order.append((batch, n_cand, len(inflight)))
            inflight.append((capacity, e_slice, q_slice, out))
        dispatch_seconds = time.perf_counter() - t_begin

        # Phase B: one sync for the whole query set, then exact counts.
        t_sync = time.perf_counter()
        jax.block_until_ready([slot[3] for slot in inflight])
        num_syncs = 1
        counts = [int(slot[3]["count"]) for slot in inflight]

        # Re-dispatch only overflowed batches at bucketed (≥ 2×) capacity;
        # the exact count makes one retry always sufficient.
        retried: list[int] = []
        results: list[object] = [slot[3] for slot in inflight]
        t_retry = time.perf_counter()
        for k, (capacity, e_slice, q_slice, _) in enumerate(inflight):
            if counts[k] > capacity:
                results[k] = self._dispatch(e_slice, q_slice, d,
                                            _bucket(counts[k], 256))
                retried.append(k)
        if retried:
            jax.block_until_ready([results[k] for k in retried])
            num_syncs += 1
        retry_seconds = time.perf_counter() - t_retry if retried else 0.0
        sync_seconds = time.perf_counter() - t_sync - retry_seconds

        # Assembly (host-side marshalling; never charged to kernel time).
        parts: list[ResultSet] = []
        stats: list[BatchStats] = []
        for batch, n_cand, slot in order:
            bs = batch.size
            if slot < 0:
                stats.append(BatchStats(bs, 0, 0, 0, 0.0, 0))
                continue
            count = counts[slot]
            part = self._batch_part(batch, results[slot], count)
            if part is not None:
                parts.append(part)
            n_retries = 1 if slot in retried else 0
            stats.append(BatchStats(
                bs, n_cand, bs * n_cand, count, 0.0, n_retries,
                retry_seconds / len(retried) if n_retries else 0.0))
        total = time.perf_counter() - t_begin
        return (ResultSet.concatenate(parts),
                ExecStats(plan.plan_seconds, total, stats,
                          num_syncs=num_syncs,
                          dispatch_seconds=dispatch_seconds,
                          sync_seconds=sync_seconds, pipelined=True))


# ----------------------------------------------------------------------
# Brute-force oracle (for tests): all pairs, no index, chunked.
# ----------------------------------------------------------------------
def brute_force(db: SegmentArray, queries: SegmentArray, d: float,
                chunk: int = 2048) -> ResultSet:
    """All-pairs reference: compares every entry to every query segment."""
    db_packed = db.packed()
    q_packed = queries.packed()
    parts: list[ResultSet] = []
    for c0 in range(0, len(db), chunk):
        e_slice = db_packed[c0:c0 + chunk]
        t_enter, t_exit, hit = ops.interaction_tiles(
            e_slice, q_packed, np.float32(d), use_pallas=False)
        hit = np.asarray(hit)
        if not hit.any():
            continue
        ei, qi = np.nonzero(hit)
        eg = c0 + ei.astype(np.int64)
        parts.append(ResultSet(
            entry_idx=eg,
            entry_traj=db.traj_id[eg].astype(np.int64),
            entry_seg=db.seg_id[eg].astype(np.int64),
            query_idx=qi.astype(np.int64),
            t_enter=np.asarray(t_enter)[ei, qi],
            t_exit=np.asarray(t_exit)[ei, qi],
        ))
    return ResultSet.concatenate(parts).sorted_canonical()
