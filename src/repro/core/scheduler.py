"""Fault-tolerant batch scheduler: deadlines, re-issue, straggler
mitigation.

At thousand-node scale a query batch (or a data-parallel step) can stall on
one slow/failed worker.  The paper's online objective (minimize response
time for an arbitrary query stream, §3) makes stalls directly user-visible,
so the engine's batch queue needs the standard production treatments:

* **deadline + re-issue**: every batch gets a deadline derived from the §8
  performance model's predicted time × a slack factor; a batch that misses
  its deadline is re-issued (to the same pool here; to another pod in a
  real deployment).  Because the engine is deterministic and stateless per
  batch, re-execution is always safe (idempotent).
* **at-least-once with deduplication**: results carry the batch id; the
  collector keeps the first completed copy of each batch, so a straggler
  finishing after its re-issue is discarded.
* **epoch-stamped state**: the scheduler's queue state (pending/done batch
  ids) is trivially checkpointable alongside the engine, so a restarted
  coordinator resumes the remaining batches only.

Execution here uses a thread pool (the CPU stand-in for per-pod executors);
``delay_hook`` lets tests inject artificial stragglers.

Public entry point: ``repro.api.TrajectoryDB.query_stream`` (and the
``repro.serve.TrajectoryQueryService`` shell on top) — callers rarely build
a ``DeadlineScheduler`` directly.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from typing import Callable

from repro.core.batching import BatchPlan
from repro.core.engine import DistanceThresholdEngine, ResultSet
from repro.core.segments import SegmentArray


@dataclasses.dataclass
class SchedulerStats:
    completed: int = 0
    reissued: int = 0
    duplicates_dropped: int = 0
    wall_seconds: float = 0.0


class DeadlineScheduler:
    """Run a BatchPlan with per-batch deadlines and straggler re-issue."""

    def __init__(self, engine: DistanceThresholdEngine, *,
                 workers: int = 2, slack: float = 4.0,
                 min_deadline: float = 0.05,
                 predict_seconds: Callable | None = None,
                 delay_hook: Callable | None = None):
        self.engine = engine
        self.workers = workers
        self.slack = slack
        self.min_deadline = min_deadline
        self.predict_seconds = predict_seconds
        self.delay_hook = delay_hook          # (batch_idx, attempt) -> None
        self._lock = threading.Lock()

    def _deadline_for(self, batch) -> float:
        if self.predict_seconds is not None:
            return max(self.slack * self.predict_seconds(batch),
                       self.min_deadline)
        return self.min_deadline

    def _run_one(self, queries: SegmentArray, d: float, plan: BatchPlan,
                 idx: int, attempt: int):
        if self.delay_hook is not None:
            self.delay_hook(idx, attempt)
        sub = BatchPlan(plan.algorithm, plan.params, [plan.batches[idx]], 0.0)
        rs, _ = self.engine.execute(queries, d, sub)
        return idx, attempt, rs

    def execute(self, queries: SegmentArray, d: float, plan: BatchPlan
                ) -> tuple[ResultSet, SchedulerStats]:
        t0 = time.perf_counter()
        stats = SchedulerStats()
        results: dict[int, ResultSet] = {}
        pool = ThreadPoolExecutor(self.workers)
        futures = {}
        deadlines = {}
        attempts = {i: 0 for i in range(plan.num_batches)}
        try:
            for i in range(plan.num_batches):
                fut = pool.submit(self._run_one, queries, d, plan, i, 0)
                futures[fut] = i
                deadlines[i] = time.perf_counter() + self._deadline_for(
                    plan.batches[i])
            while len(results) < plan.num_batches:
                done, _ = wait(list(futures), timeout=0.01,
                               return_when=FIRST_COMPLETED)
                now = time.perf_counter()
                for fut in done:
                    i = futures.pop(fut)
                    idx, attempt, rs = fut.result()
                    with self._lock:
                        if idx in results:
                            stats.duplicates_dropped += 1
                        else:
                            results[idx] = rs
                            stats.completed += 1
                # re-issue batches past deadline that are still incomplete
                pending = {i for i in futures.values()}
                for i in list(pending):
                    if i in results or now <= deadlines.get(i, now + 1):
                        continue
                    attempts[i] += 1
                    stats.reissued += 1
                    deadlines[i] = now + self._deadline_for(plan.batches[i])
                    fut = pool.submit(self._run_one, queries, d, plan, i,
                                      attempts[i])
                    futures[fut] = i
        finally:
            pool.shutdown(wait=False, cancel_futures=True)
        ordered = [results[i] for i in range(plan.num_batches)]
        stats.wall_seconds = time.perf_counter() - t0
        return ResultSet.concatenate(ordered), stats
