"""Fault-tolerant batch scheduler: deadlines, re-issue, straggler
mitigation — over *batch groups*.

At thousand-node scale a query batch (or a data-parallel step) can stall on
one slow/failed worker.  The paper's online objective (minimize response
time for an arbitrary query stream, §3) makes stalls directly user-visible,
so the engine's batch queue needs the standard production treatments:

* **batch groups**: the scheduler's unit of work is a *group* of
  consecutive batches, not a single batch.  Each worker call executes its
  group as one sub-plan through the engine's pipelined executor — one
  two-phase dispatch (≤ 2 host syncs) per group — so the O(1)-sync
  property amortizes inside a stream too, instead of degrading back to one
  sync per batch the moment the scheduler is involved.  Group size
  defaults to ≥ 2 batches per call (see :meth:`DeadlineScheduler.groups`).
* **deadline + re-issue**: every group gets a deadline derived from the §8
  performance model's predicted time *summed over the group's batches* × a
  slack factor; a group that misses its deadline is re-issued (to the same
  pool here; to another pod in a real deployment).  Because the engine is
  deterministic and stateless per batch, re-executing a whole group is
  always safe (idempotent).
* **at-least-once with deduplication**: results carry the group id; the
  collector keeps the first completed copy of each group, so a straggler
  finishing after its re-issue is discarded.
* **epoch-stamped state**: the scheduler's queue state (pending/done group
  ids) is trivially checkpointable alongside the engine, so a restarted
  coordinator resumes the remaining groups only.

Execution here uses a thread pool (the CPU stand-in for per-pod executors);
``delay_hook(group_idx, attempt)`` lets tests inject artificial stragglers.

Public entry point: ``repro.api.TrajectoryDB.query_stream`` (and the
``repro.serve.TrajectoryQueryService`` shell on top) — callers rarely build
a ``DeadlineScheduler`` directly.  ``ExecutionPolicy.stream_group_size``
sets the group size through the facade.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from typing import Callable

from repro import faults
from repro.core.batching import BatchPlan
from repro.core.engine import DistanceThresholdEngine, ResultSet
from repro.core.planner import (DEFAULT_CAPACITY, QueryPlan, as_query_plan,
                                derive_group_size, make_groups)
from repro.core.segments import SegmentArray


@dataclasses.dataclass
class SchedulerStats:
    completed: int = 0             #: batches completed (first copy)
    groups: int = 0                #: batch groups formed (worker-call units)
    reissued: int = 0              #: groups re-issued (deadline or failure)
    duplicates_dropped: int = 0    #: late duplicate group completions dropped
    failures: int = 0              #: worker executions that raised (PR 10)
    wall_seconds: float = 0.0
    group_sizes: list = dataclasses.field(default_factory=list)
    #: per-pod routing accounting when the engine is a ``PodRouter``
    #: (``repro.core.distributed.RoutingStats``); ``None`` otherwise.
    routing: object = None

    @property
    def batches_per_call(self) -> float:
        """Mean batches dispatched per worker call — ≥ 2 by default when
        the plan has ≥ 2 batches (the pipelined-stream property)."""
        return (sum(self.group_sizes) / len(self.group_sizes)
                if self.group_sizes else 0.0)


class DeadlineScheduler:
    """Run a plan as deadline-tracked batch *groups* with straggler
    re-issue; each group is one pipelined engine dispatch.

    ``engine`` is anything with the engines' ``execute(queries, d, plan)``
    contract — the single-device ``DistanceThresholdEngine``, the mesh
    ``ShardedEngine``, or a ``repro.core.distributed.PodRouter`` (the
    per-pod routing layer ``query_stream(backend="shard")`` wraps around
    the sharded engine)."""

    def __init__(self, engine: DistanceThresholdEngine, *,
                 workers: int = 2, slack: float = 4.0,
                 min_deadline: float = 0.05,
                 predict_seconds: Callable | None = None,
                 delay_hook: Callable | None = None,
                 group_size: int | None = None,
                 max_failures: int = 3):
        self.engine = engine
        self.workers = workers
        self.slack = slack
        self.min_deadline = min_deadline
        self.predict_seconds = predict_seconds
        self.delay_hook = delay_hook          # (group_idx, attempt) -> None
        self.group_size = group_size          # None -> auto (>= 2 per call)
        # Bounded *failure* re-issue (PR 10): a group whose worker raises
        # is re-run like a deadline straggler, at most max_failures
        # executions; the max_failures-th failure propagates to the caller.
        self.max_failures = int(max_failures)
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def groups(self, num_batches: int, batches=None,
               runs=None) -> list[list[int]]:
        """Partition batch indices into worker-call groups.

        ``group_size=None`` auto-sizes so every call carries ≥ 2 batches
        (a lone trailing remainder is folded into the previous group)
        while keeping at least ~2 groups per worker in flight (re-issue
        granularity): ``max(2, ceil(n / (2·workers)))``.  When the plan's
        ``batches`` are supplied, the §8-model hit-volume heuristic
        (``repro.core.planner.derive_group_size`` — marshal time ≈ hit
        volume) can additionally *shrink* auto groups so one worker call
        never marshals more than a group's worth of predicted result rows.
        An explicit ``group_size`` is honored as given, remainder group
        included.  ``runs`` (spatial-pruning split runs — see
        ``QueryPlan.runs``) keeps sibling batches of one query range in
        the same group, so ``on_group`` deliveries stay canonical slices.
        """
        if num_batches <= 0:
            return []
        gs = self.group_size
        auto = gs is None
        if auto:
            gs = max(2, math.ceil(num_batches / (2 * self.workers)))
            if batches is not None:
                model_gs = derive_group_size(batches)
                if model_gs is not None:
                    gs = min(gs, max(model_gs, 2))
        gs = max(1, min(int(gs), num_batches))
        out = make_groups(num_batches, gs, runs=runs)
        if auto and len(out) >= 2 and len(out[-1]) == 1:
            out[-2].extend(out.pop())
        return out

    def _deadline_for(self, batches) -> float:
        """§8 model-derived deadline for a whole group: the predictions sum
        over the group's batches (one pipelined dispatch executes them
        back-to-back), scaled by the slack factor.  Without a predictor
        the floor scales with the group size — a call doing k batches of
        work gets k batches of deadline."""
        if self.predict_seconds is not None:
            predicted = sum(self.predict_seconds(b) for b in batches)
            return max(self.slack * predicted, self.min_deadline)
        return self.min_deadline * max(len(batches), 1)

    def _run_one(self, queries: SegmentArray, d: float, plan: QueryPlan,
                 group_idx: int, group: list[int], attempt: int):
        if self.delay_hook is not None:
            self.delay_hook(group_idx, attempt)
        if faults.armed():
            faults.inject("scheduler.worker", group=group_idx,
                          attempt=attempt)
        sub = plan.subplan(group)
        rs, _ = self.engine.execute(queries, d, sub)
        return group_idx, attempt, rs

    # ------------------------------------------------------------------
    def execute(self, queries: SegmentArray, d: float,
                plan: BatchPlan | QueryPlan, *,
                on_group: Callable | None = None
                ) -> tuple[ResultSet, SchedulerStats]:
        """Run the plan; ``on_group(group_idx, batch_indices, results)``
        fires on the *first* completion of each group (duplicates from
        re-issued stragglers never reach it) — incremental delivery for
        streaming consumers of the scheduler path."""
        t0 = time.perf_counter()
        capacity = getattr(self.engine, "default_capacity", None)
        qplan = as_query_plan(plan, default_capacity=capacity
                              if capacity is not None else DEFAULT_CAPACITY)
        groups = self.groups(qplan.num_batches, qplan.batches,
                             getattr(qplan, "runs", None))
        stats = SchedulerStats(groups=len(groups),
                               group_sizes=[len(g) for g in groups],
                               routing=getattr(self.engine, "stats", None))
        results: dict[int, ResultSet] = {}
        pool = ThreadPoolExecutor(self.workers)
        futures = {}
        deadlines = {}
        attempts = {g: 0 for g in range(len(groups))}
        failed: dict[int, int] = {}
        try:
            for g, group in enumerate(groups):
                fut = pool.submit(self._run_one, queries, d, qplan, g,
                                  group, 0)
                futures[fut] = g
                deadlines[g] = time.perf_counter() + self._deadline_for(
                    [qplan.batches[i] for i in group])
            while len(results) < len(groups):
                done, _ = wait(list(futures), timeout=0.01,
                               return_when=FIRST_COMPLETED)
                now = time.perf_counter()
                # Deliberate syncs, not pipeline leaks: ``done`` holds only
                # *completed* worker futures (the group's device work and
                # marshalling already finished inside engine.execute), so
                # collecting them here is the scheduler's sanctioned
                # group-granular sync — the analogue of the executors'
                # phase B, needed for deadline tracking and re-issue.
                for fut in done:                     # lint: sync-point
                    g_of = futures.pop(fut)
                    try:
                        g, attempt, rs = fut.result()    # lint: sync-point
                    except Exception:
                        # Failed execution: re-issue like a deadline
                        # straggler, bounded by max_failures; the final
                        # failure propagates (structured errors like
                        # CapacityError surface unchanged).
                        with self._lock:
                            have = g_of in results
                        stats.failures += 1
                        if have:
                            stats.duplicates_dropped += 1
                            continue
                        failed[g_of] = failed.get(g_of, 0) + 1
                        if failed[g_of] >= self.max_failures:
                            raise
                        attempts[g_of] += 1
                        stats.reissued += 1
                        deadlines[g_of] = now + self._deadline_for(
                            [qplan.batches[i] for i in groups[g_of]])
                        fut2 = pool.submit(self._run_one, queries, d,
                                           qplan, g_of, groups[g_of],
                                           attempts[g_of])
                        futures[fut2] = g_of
                        continue
                    with self._lock:
                        if g in results:
                            stats.duplicates_dropped += 1
                        else:
                            results[g] = rs
                            stats.completed += len(groups[g])
                            if on_group is not None:
                                on_group(g, list(groups[g]), rs)
                # re-issue groups past deadline that are still incomplete
                pending = {g for g in futures.values()}
                for g in list(pending):
                    if g in results or now <= deadlines.get(g, now + 1):
                        continue
                    attempts[g] += 1
                    stats.reissued += 1
                    deadlines[g] = now + self._deadline_for(
                        [qplan.batches[i] for i in groups[g]])
                    fut = pool.submit(self._run_one, queries, d, qplan, g,
                                      groups[g], attempts[g])
                    futures[fut] = g
        finally:
            pool.shutdown(wait=False, cancel_futures=True)
        ordered = [results[g] for g in range(len(groups))]
        stats.wall_seconds = time.perf_counter() - t0
        return ResultSet.concatenate(ordered), stats
