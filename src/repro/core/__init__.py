"""The paper's primary contribution: in-memory distance-threshold query
processing with a GPU/TPU-friendly temporal-bin index (no index trees on
the hot path), batched query execution, and batch-generation algorithms."""
from repro.core.segments import SegmentArray, pad_count  # noqa: F401
from repro.core.index import TemporalBinIndex, DEFAULT_NUM_BINS  # noqa: F401
from repro.core.batching import (  # noqa: F401
    ALGORITHMS, BatchPlan, QueryBatch, greedysetsplit_max, greedysetsplit_min,
    periodic, setsplit_fixed, setsplit_max, setsplit_minmax)
from repro.core.engine import (  # noqa: F401
    DistanceThresholdEngine, ExecStats, ResultSet, brute_force)
