"""The paper's primary contribution: in-memory distance-threshold query
processing with a GPU/TPU-friendly temporal-bin index (no index trees on
the hot path), batched query execution, and batch-generation algorithms.

The stable entry point for *querying* is the :mod:`repro.api` facade
(``TrajectoryDB``); the engine-level names re-exported here
(``DistanceThresholdEngine``, ``brute_force``, …) remain importable for one
release but emit a ``DeprecationWarning`` — new code should go through the
facade, which owns sorting, planning and caller-order result mapping.
Importing from the defining submodules (``repro.core.engine`` etc.) stays
supported and warning-free for internal/advanced use.
"""
import warnings

from repro.core.segments import SegmentArray, pad_count  # noqa: F401
from repro.core.index import TemporalBinIndex, DEFAULT_NUM_BINS  # noqa: F401
from repro.core.batching import (  # noqa: F401
    ALGORITHMS, BatchPlan, QueryBatch, greedysetsplit_max, greedysetsplit_min,
    periodic, setsplit_fixed, setsplit_max, setsplit_minmax)

# Deprecated engine-level re-exports: resolved lazily so touching them (and
# only them) warns.  repro.core.engine itself is NOT deprecated.
_DEPRECATED_ENGINE_NAMES = {
    "DistanceThresholdEngine": "repro.api.TrajectoryDB",
    "ResultSet": "repro.api.QueryResult",
    "ExecStats": "repro.api.QueryResult.stats",
    "brute_force": "repro.api.TrajectoryDB.query(..., backend='brute')",
}


def __getattr__(name: str):
    if name in _DEPRECATED_ENGINE_NAMES:
        warnings.warn(
            f"repro.core.{name} is deprecated; use "
            f"{_DEPRECATED_ENGINE_NAMES[name]} (see repro.api). "
            f"Importing from repro.core.engine directly remains supported.",
            DeprecationWarning, stacklevel=2)
        from repro.core import engine
        return getattr(engine, name)
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_DEPRECATED_ENGINE_NAMES))
