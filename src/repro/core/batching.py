"""Query-batch generation algorithms (paper §6, Algorithms 2–4).

All algorithms take the query segments *sorted by non-decreasing t_start*
(the paper's precondition) and partition them into batches of **contiguous**
query segments.  A batch is fully described by its index range
``[q_first, q_last]`` into the sorted query array plus its temporal extent
``[qt0, qt1]`` (``qt0 = ts[q_first]`` by sortedness; ``qt1`` is the running
max of ``te`` over the range, maintained in O(1) across merges).

``numInts(batch) = |batch| × |E_batch|`` where ``|E_batch|`` is the number
of candidate entry segments given by the temporal-bin index (paper §4) —
this is the quantity every algorithm below minimizes increases of.

**Pruning-aware pricing (PR 5).**  With spatial pruning enabled the true
per-batch workload is the *pruned* candidate count, so every merge
decision should price it: pass a :class:`SpatialInteractionCounter` as
``counter=`` and the merge loops evaluate ``numInts`` against the
temporal-bin index's coarse per-bin-MBR grid (conservative, vectorized)
while maintaining each batch's query-MBR union incrementally across
merges.  A merge of two spatially distant batches then has a *positive*
cost even when their temporal extents nest — the algorithms keep
spatially coherent batches, which is what makes the downstream sub-range
split (``repro.core.planner``) effective.  ``counter=None`` (the default)
prices the paper's temporal-only count, bit-for-bit as before.

Algorithms:

* :func:`periodic` — fixed batch size ``s`` (paper §6.1).
* :func:`setsplit_fixed` — Algorithm 2: O(|Q|²) best-merge loop down to a
  target number of batches.
* :func:`setsplit_minmax` — Algorithm 3: best-merge loop with a max-size
  constraint, then a second phase merging undersized batches left/right.
* :func:`setsplit_max` — Algorithm 3 with ``min=1`` (paper §6.2 last line).
* :func:`greedysetsplit_min` / :func:`greedysetsplit_max` — Algorithm 4:
  one pass of "free" merges (merges that add zero interactions), then one
  constraint pass.  O(|Q|) merge decisions.

The SETSPLIT loops are vectorized with numpy (all adjacent-pair merge costs
are evaluated per iteration with ``candidate_range_batch``), which keeps
the quadratic algorithms usable at |Q| of a few thousand.  The *semantics*
are line-for-line the paper's: each iteration merges the adjacent pair with
the smallest ``numIntsMerged − numIntsUnmerged``.

Public entry point: algorithm selection lives in
``repro.api.ExecutionPolicy(batching=..., batch_params=...)``; the facade
calls into :data:`ALGORITHMS` and owns the sortedness precondition.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core.index import TemporalBinIndex
from repro.core.segments import SegmentArray


@dataclasses.dataclass(frozen=True)
class QueryBatch:
    """A contiguous run of sorted query segments plus its candidate range."""

    q_first: int           # inclusive index into the sorted query array
    q_last: int            # inclusive
    qt0: float             # temporal extent start (= ts[q_first])
    qt1: float             # temporal extent end (= max te over the range)
    cand_first: int        # inclusive candidate entry index (0, -1 if empty)
    cand_last: int         # inclusive
    num_ints: int          # |batch| × num_candidates

    @property
    def size(self) -> int:
        return self.q_last - self.q_first + 1

    @property
    def num_candidates(self) -> int:
        return max(self.cand_last - self.cand_first + 1, 0)


@dataclasses.dataclass
class BatchPlan:
    """Output of a batching algorithm plus provenance for EXPERIMENTS.md."""

    algorithm: str
    params: dict
    batches: list[QueryBatch]
    plan_seconds: float    # time spent computing the plan (paper §7.4 charges this)

    @property
    def total_interactions(self) -> int:
        return int(sum(b.num_ints for b in self.batches))

    @property
    def num_batches(self) -> int:
        return len(self.batches)

    def sizes(self) -> np.ndarray:
        return np.array([b.size for b in self.batches], dtype=np.int64)


class SpatialInteractionCounter:
    """Prices ``numInts`` with spatial pruning folded in.

    Bound to one (index, sorted query set, threshold): per-batch candidate
    counts come from the index's coarse per-bin-MBR estimate
    (:meth:`~repro.core.index.TemporalBinIndex.
    estimate_pruned_candidates_batch`) evaluated against each batch's
    query-MBR union.  ``level="box"`` prices against the K-box-per-bin
    hierarchy (``pruning="hierarchical"``); ``max_subranges`` folds the
    planner's sub-range cap into the price (the cap can re-admit a
    fragmented extent's gap segments, and the coarse grid charges a
    conservative surcharge for that — see the estimate's docstring), so
    the priced count tracks the capped dispatched workload instead of the
    uncapped ideal.
    """

    def __init__(self, index: TemporalBinIndex, queries: SegmentArray,
                 d: float, *, level: str = "bin",
                 max_subranges: int | None = None):
        self.index = index
        self.d = float(d)
        self.level = level
        self.max_subranges = max_subranges
        self.qlo, self.qhi = queries.mbrs()      # (nq, 3) float64

    def counts(self, qt0, qt1, lo, hi) -> np.ndarray:
        """Pruned candidate counts for batches with extents (qt0, qt1) and
        query-MBR unions (lo, hi) — all stacked arrays."""
        return self.index.estimate_pruned_candidates_batch(
            qt0, qt1, lo, hi, self.d, level=self.level,
            max_subranges=self.max_subranges)


# ----------------------------------------------------------------------
# internal representation used by the merge loops: parallel arrays over
# the current batch list B.  Batches are contiguous and ordered, so batch
# k is [starts[k], starts[k] + sizes[k] - 1].
# ----------------------------------------------------------------------
class _BatchState:
    def __init__(self, index: TemporalBinIndex, queries: SegmentArray,
                 counter: SpatialInteractionCounter | None = None):
        if not queries.is_sorted():
            raise ValueError("queries must be sorted by t_start (paper §4)")
        nq = len(queries)
        if nq == 0:
            raise ValueError("empty query set")
        self.index = index
        self.counter = counter
        self.starts = np.arange(nq, dtype=np.int64)
        self.sizes = np.ones(nq, dtype=np.int64)
        self.qt0 = queries.ts.astype(np.float64).copy()
        self.qt1 = queries.te.astype(np.float64).copy()
        if counter is not None:
            # Per-batch query-MBR unions, maintained across merges.
            self.mlo = counter.qlo.copy()
            self.mhi = counter.qhi.copy()
            self.num_ints = self.sizes * counter.counts(
                self.qt0, self.qt1, self.mlo, self.mhi)
        else:
            self.mlo = self.mhi = None
            self.num_ints = self.sizes * index.num_candidates_batch(
                self.qt0, self.qt1)

    def __len__(self) -> int:
        return len(self.starts)

    def merge_costs(self) -> np.ndarray:
        """numIntsMerged − numIntsUnmerged for every adjacent pair (vectorized)."""
        m_qt0 = self.qt0[:-1]                                 # sorted ⇒ min is left's
        m_qt1 = np.maximum(self.qt1[:-1], self.qt1[1:])
        m_size = self.sizes[:-1] + self.sizes[1:]
        if self.counter is not None:
            m_lo = np.minimum(self.mlo[:-1], self.mlo[1:])
            m_hi = np.maximum(self.mhi[:-1], self.mhi[1:])
            merged = m_size * self.counter.counts(m_qt0, m_qt1, m_lo, m_hi)
        else:
            merged = m_size * self.index.num_candidates_batch(m_qt0, m_qt1)
        return merged - (self.num_ints[:-1] + self.num_ints[1:])

    def merged_sizes(self) -> np.ndarray:
        return self.sizes[:-1] + self.sizes[1:]

    def merged_ints(self, i: int) -> int:
        """numInts of the would-be merge of batches i and i+1 (the scalar
        the GREEDYSETSPLIT free-merge test and the MINMAX fix-up use)."""
        qt0 = self.qt0[i]
        qt1 = max(self.qt1[i], self.qt1[i + 1])
        size = int(self.sizes[i] + self.sizes[i + 1])
        if self.counter is not None:
            lo = np.minimum(self.mlo[i], self.mlo[i + 1])
            hi = np.maximum(self.mhi[i], self.mhi[i + 1])
            return size * int(self.counter.counts(
                np.array([qt0]), np.array([qt1]), lo[None], hi[None])[0])
        return size * self.index.num_candidates(qt0, qt1)

    def merge_at(self, i: int) -> None:
        """Merge batches i and i+1 in place (paper's merge + removeElementAt)."""
        self.qt1[i] = max(self.qt1[i], self.qt1[i + 1])
        self.sizes[i] += self.sizes[i + 1]
        if self.counter is not None:
            self.mlo[i] = np.minimum(self.mlo[i], self.mlo[i + 1])
            self.mhi[i] = np.maximum(self.mhi[i], self.mhi[i + 1])
            self.num_ints[i] = self.sizes[i] * int(self.counter.counts(
                self.qt0[i:i + 1], self.qt1[i:i + 1],
                self.mlo[i][None], self.mhi[i][None])[0])
        else:
            self.num_ints[i] = self.sizes[i] * self.index.num_candidates(
                self.qt0[i], self.qt1[i])
        for name in ("starts", "sizes", "qt0", "qt1", "num_ints"):
            arr = getattr(self, name)
            setattr(self, name, np.delete(arr, i + 1))
        if self.counter is not None:
            self.mlo = np.delete(self.mlo, i + 1, axis=0)
            self.mhi = np.delete(self.mhi, i + 1, axis=0)

    def to_batches(self) -> list[QueryBatch]:
        first, last = self.index.candidate_range_batch(self.qt0, self.qt1)
        out = []
        for k in range(len(self.starts)):
            out.append(QueryBatch(
                q_first=int(self.starts[k]),
                q_last=int(self.starts[k] + self.sizes[k] - 1),
                qt0=float(self.qt0[k]), qt1=float(self.qt1[k]),
                cand_first=int(first[k]), cand_last=int(last[k]),
                num_ints=int(self.num_ints[k]),
            ))
        return out


def _finish(name: str, params: dict, state_or_batches, t_start: float) -> BatchPlan:
    batches = (state_or_batches.to_batches()
               if isinstance(state_or_batches, _BatchState) else state_or_batches)
    return BatchPlan(algorithm=name, params=params, batches=batches,
                     plan_seconds=time.perf_counter() - t_start)


# ----------------------------------------------------------------------
# PERIODIC (paper §6.1)
# ----------------------------------------------------------------------
def periodic(index: TemporalBinIndex, queries: SegmentArray, s: int, *,
             counter: SpatialInteractionCounter | None = None) -> BatchPlan:
    """Fixed-size batches of ``s`` consecutive sorted query segments.

    PERIODIC makes no merge decisions, so ``counter`` is accepted for
    interface uniformity only — the pruned workload is priced downstream
    by the planner's sub-range refinement.
    """
    del counter
    t_begin = time.perf_counter()
    if s <= 0:
        raise ValueError("batch size must be positive")
    nq = len(queries)
    starts = np.arange(0, nq, s, dtype=np.int64)
    ends = np.minimum(starts + s, nq) - 1
    qt0 = queries.ts[starts].astype(np.float64)
    # max te within each chunk, via a prefix-max free approach: reduceat.
    qt1 = np.maximum.reduceat(queries.te.astype(np.float64), starts)
    first, last = index.candidate_range_batch(qt0, qt1)
    sizes = ends - starts + 1
    ints = sizes * np.maximum(last - first + 1, 0)
    batches = [QueryBatch(int(a), int(b), float(t0), float(t1), int(f), int(l), int(i))
               for a, b, t0, t1, f, l, i
               in zip(starts, ends, qt0, qt1, first, last, ints)]
    return _finish("periodic", {"s": s}, batches, t_begin)


# ----------------------------------------------------------------------
# SETSPLIT (paper §6.2, Algorithms 2 & 3)
# ----------------------------------------------------------------------
def setsplit_fixed(index: TemporalBinIndex, queries: SegmentArray,
                   num_batches: int, *,
                   counter: SpatialInteractionCounter | None = None
                   ) -> BatchPlan:
    """Algorithm 2: merge the cheapest adjacent pair until |B| = numBatches."""
    t_begin = time.perf_counter()
    st = _BatchState(index, queries, counter)
    num_batches = max(1, num_batches)
    while len(st) > num_batches:
        costs = st.merge_costs()
        st.merge_at(int(np.argmin(costs)))
    return _finish("setsplit-fixed", {"num_batches": num_batches}, st, t_begin)


def setsplit_minmax(index: TemporalBinIndex, queries: SegmentArray,
                    min_size: int, max_size: int, *,
                    counter: SpatialInteractionCounter | None = None
                    ) -> BatchPlan:
    """Algorithm 3: constrained best-merge loop + undersize fix-up passes."""
    t_begin = time.perf_counter()
    if min_size > max_size:
        raise ValueError("min_size > max_size")
    st = _BatchState(index, queries, counter)
    # Phase 1 (lines 3–21): best merge among pairs whose merged size <= max.
    while True:
        if len(st) == 1:
            break
        costs = st.merge_costs().astype(np.float64)
        costs[st.merged_sizes() > max_size] = np.inf   # line 6: skip oversize merges
        i = int(np.argmin(costs))
        if not np.isfinite(costs[i]):                  # line 16: minDiff = +inf
            break
        st.merge_at(i)
    # Phase 2 (lines 22–40): merge undersized batches with cheaper neighbour.
    while True:
        small = np.nonzero(st.sizes < min_size)[0]
        if small.size == 0 or len(st) == 1:
            break
        i = int(small[0])
        left = st.merged_ints(i - 1) if i > 0 else np.inf
        right = st.merged_ints(i) if i < len(st) - 1 else np.inf
        if left < right:
            st.merge_at(i - 1)
        else:
            st.merge_at(i)
    return _finish("setsplit-minmax", {"min": min_size, "max": max_size}, st, t_begin)


def setsplit_max(index: TemporalBinIndex, queries: SegmentArray,
                 max_size: int, *,
                 counter: SpatialInteractionCounter | None = None
                 ) -> BatchPlan:
    """SETSPLIT-MINMAX with min = 1 (paper §6.2, final paragraph)."""
    plan = setsplit_minmax(index, queries, 1, max_size, counter=counter)
    plan.algorithm = "setsplit-max"
    plan.params = {"max": max_size}
    return plan


# ----------------------------------------------------------------------
# GREEDYSETSPLIT (paper §6.3, Algorithm 4)
# ----------------------------------------------------------------------
def _greedy(index: TemporalBinIndex, queries: SegmentArray, bound: int,
            variant: str,
            counter: SpatialInteractionCounter | None = None) -> BatchPlan:
    t_begin = time.perf_counter()
    st = _BatchState(index, queries, counter)
    # Phase 1 (lines 4–11): single pass of free merges.  A merge is free iff
    # numInts(merge) == numInts(B[i]) + numInts(B[i+1]).
    i = 0
    while i < len(st) - 1:
        if st.merged_ints(i) == st.num_ints[i] + st.num_ints[i + 1]:
            st.merge_at(i)
        else:
            i += 1
    # Phase 2 (lines 13–20): constraint pass.
    i = 0
    while i < len(st) - 1:
        if variant == "min":
            if st.sizes[i] < bound:
                st.merge_at(i)
            else:
                i += 1
        else:  # "max": paper swaps the test and the clauses — merge while the
            # current batch has not yet exceeded the bound.  The bound is soft
            # (the merge that crosses it is still performed), exactly as the
            # literal transformation of line 14 dictates.
            if st.sizes[i] > bound:
                i += 1
            else:
                st.merge_at(i)
    return _finish(f"greedysetsplit-{variant}", {"bound": bound}, st, t_begin)


def greedysetsplit_min(index: TemporalBinIndex, queries: SegmentArray,
                       bound: int, *,
                       counter: SpatialInteractionCounter | None = None
                       ) -> BatchPlan:
    """Algorithm 4: free merges, then merge any batch smaller than ``bound``."""
    return _greedy(index, queries, bound, "min", counter)


def greedysetsplit_max(index: TemporalBinIndex, queries: SegmentArray,
                       bound: int, *,
                       counter: SpatialInteractionCounter | None = None
                       ) -> BatchPlan:
    """Algorithm 4 MAX variant (paper §6.3 prose)."""
    return _greedy(index, queries, bound, "max", counter)


ALGORITHMS: dict[str, Callable] = {
    "periodic": periodic,
    "setsplit-fixed": setsplit_fixed,
    "setsplit-max": setsplit_max,
    "setsplit-minmax": setsplit_minmax,
    "greedysetsplit-min": greedysetsplit_min,
    "greedysetsplit-max": greedysetsplit_max,
}
