"""Segment storage for the trajectory database.

The paper (§3) defines the database ``D`` as ``n`` 4-D line segments, each
with a spatiotemporal start point, end point, a segment id and a trajectory
id.  We store segments as a struct-of-arrays so that (a) the temporal-bin
index's contiguous candidate ranges translate into dense slices, and (b) the
Pallas kernel's BlockSpecs see flat, padded, tile-aligned arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

# Column order used when segments are packed into a single (n, 8) matrix for
# device transfer: spatial start, spatial end, temporal extent.
PACKED_COLUMNS = ("xs", "ys", "zs", "xe", "ye", "ze", "ts", "te")


@dataclasses.dataclass
class SegmentArray:
    """Struct-of-arrays segment store.

    All spatial/temporal arrays are float32 of shape (n,); ids are int32.
    ``ts``/``te`` are the segment's temporal extent (paper: t_i^start,
    t_i^end).  Invariant after :meth:`sort_by_tstart`: ``ts`` is
    non-decreasing, which the temporal-bin index requires.
    """

    xs: np.ndarray
    ys: np.ndarray
    zs: np.ndarray
    xe: np.ndarray
    ye: np.ndarray
    ze: np.ndarray
    ts: np.ndarray
    te: np.ndarray
    seg_id: np.ndarray
    traj_id: np.ndarray

    def __post_init__(self) -> None:
        n = len(self.xs)
        for name in PACKED_COLUMNS:
            arr = np.asarray(getattr(self, name), dtype=np.float32)
            if arr.shape != (n,):
                raise ValueError(f"column {name} has shape {arr.shape}, want ({n},)")
            setattr(self, name, arr)
        for name in ("seg_id", "traj_id"):
            arr = np.asarray(getattr(self, name), dtype=np.int32)
            if arr.shape != (n,):
                raise ValueError(f"column {name} has shape {arr.shape}, want ({n},)")
            setattr(self, name, arr)
        if np.any(self.te < self.ts):
            raise ValueError("segment end time precedes start time")

    def __len__(self) -> int:
        return int(self.xs.shape[0])

    # ------------------------------------------------------------------
    # construction helpers
    # ------------------------------------------------------------------
    @staticmethod
    def from_trajectories(points: Sequence[np.ndarray], times: Sequence[np.ndarray],
                          traj_ids: Sequence[int] | None = None) -> "SegmentArray":
        """Build segments from per-trajectory polylines.

        ``points[k]`` is (m_k, 3) float array of positions, ``times[k]`` is
        (m_k,) non-decreasing timestamps.  Each consecutive pair of points
        becomes one entry segment (paper §2.2: polyline approximation).
        """
        cols: dict[str, list[np.ndarray]] = {c: [] for c in PACKED_COLUMNS}
        seg_ids: list[np.ndarray] = []
        trj_ids: list[np.ndarray] = []
        for k, (pts, tms) in enumerate(zip(points, times)):
            pts = np.asarray(pts, dtype=np.float32)
            tms = np.asarray(tms, dtype=np.float32)
            if pts.ndim != 2 or pts.shape[1] != 3:
                raise ValueError("points must be (m, 3)")
            if pts.shape[0] != tms.shape[0]:
                raise ValueError("points/times length mismatch")
            m = pts.shape[0] - 1
            if m <= 0:
                continue
            cols["xs"].append(pts[:-1, 0]); cols["ys"].append(pts[:-1, 1])
            cols["zs"].append(pts[:-1, 2])
            cols["xe"].append(pts[1:, 0]); cols["ye"].append(pts[1:, 1])
            cols["ze"].append(pts[1:, 2])
            cols["ts"].append(tms[:-1]); cols["te"].append(tms[1:])
            seg_ids.append(np.arange(m, dtype=np.int32))
            tid = k if traj_ids is None else traj_ids[k]
            trj_ids.append(np.full(m, tid, dtype=np.int32))
        if not seg_ids:
            return SegmentArray.empty()
        return SegmentArray(
            **{c: np.concatenate(cols[c]) for c in PACKED_COLUMNS},
            seg_id=np.concatenate(seg_ids),
            traj_id=np.concatenate(trj_ids),
        )

    @staticmethod
    def empty() -> "SegmentArray":
        z = np.zeros(0, dtype=np.float32)
        zi = np.zeros(0, dtype=np.int32)
        return SegmentArray(z, z, z, z, z, z, z, z, zi, zi)

    @staticmethod
    def concatenate(parts: Sequence["SegmentArray"]) -> "SegmentArray":
        return SegmentArray(
            **{c: np.concatenate([getattr(p, c) for p in parts]) for c in PACKED_COLUMNS},
            seg_id=np.concatenate([p.seg_id for p in parts]),
            traj_id=np.concatenate([p.traj_id for p in parts]),
        )

    # ------------------------------------------------------------------
    # views / transforms
    # ------------------------------------------------------------------
    def take(self, idx) -> "SegmentArray":
        return SegmentArray(
            **{c: getattr(self, c)[idx] for c in PACKED_COLUMNS},
            seg_id=self.seg_id[idx], traj_id=self.traj_id[idx],
        )

    def slice(self, first: int, last: int) -> "SegmentArray":
        """Inclusive contiguous slice [first, last] (paper's candidate range)."""
        return self.take(np.s_[first:last + 1])

    def sort_by_tstart(self) -> "SegmentArray":
        """Sort by non-decreasing t_start (paper §4, the index precondition).

        Stable so that equal-t_start segments keep (traj, seg) order, making
        results reproducible.
        """
        order = np.argsort(self.ts, kind="stable")
        return self.take(order)

    def is_sorted(self) -> bool:
        return bool(np.all(self.ts[1:] >= self.ts[:-1])) if len(self) > 1 else True

    def mbrs(self) -> tuple[np.ndarray, np.ndarray]:
        """Per-segment axis-aligned spatial bounding boxes, ``(lo, hi)`` of
        shape (n, 3) float64.  A segment moves linearly between its
        endpoints, so its position at every instant of its temporal extent
        lies inside the box spanned by the two endpoints — the invariant
        the spatial pruning layer (``repro.core.index``) relies on."""
        p0 = np.stack([self.xs, self.ys, self.zs], axis=1).astype(np.float64)
        p1 = np.stack([self.xe, self.ye, self.ze], axis=1).astype(np.float64)
        return np.minimum(p0, p1), np.maximum(p0, p1)

    @property
    def temporal_extent(self) -> tuple[float, float]:
        if len(self) == 0:
            return (0.0, 0.0)
        return float(self.ts.min()), float(self.te.max())

    # ------------------------------------------------------------------
    # device packing
    # ------------------------------------------------------------------
    def packed(self, pad_to: int | None = None, pad_multiple: int | None = None) -> np.ndarray:
        """Pack into an (n_padded, 8) float32 matrix for device transfer.

        Padding rows get a temporal extent strictly outside the data's range
        so they can never produce a temporal hit (branchless masking relies
        on this): ts = te = t_max_data + 1 with zero spatial extent.
        """
        n = len(self)
        target = n
        if pad_to is not None:
            target = max(target, pad_to)
        if pad_multiple is not None and pad_multiple > 0:
            target = ((max(target, 1) + pad_multiple - 1) // pad_multiple) * pad_multiple
        out = np.empty((target, 8), dtype=np.float32)
        for j, c in enumerate(PACKED_COLUMNS):
            out[:n, j] = getattr(self, c)
        if target > n:
            _, tmax = self.temporal_extent
            pad_t = np.float32(tmax + 1.0)
            out[n:, :] = 0.0
            out[n:, 6] = pad_t  # ts
            out[n:, 7] = pad_t  # te  (zero-length extent outside data range)
        return out

    def ids_packed(self, pad_to: int | None = None, pad_multiple: int | None = None) -> np.ndarray:
        n = len(self)
        target = n
        if pad_to is not None:
            target = max(target, pad_to)
        if pad_multiple is not None and pad_multiple > 0:
            target = ((max(target, 1) + pad_multiple - 1) // pad_multiple) * pad_multiple
        out = np.full((target, 2), -1, dtype=np.int32)
        out[:n, 0] = self.traj_id
        out[:n, 1] = self.seg_id
        return out


def pad_count(n: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` that is >= max(n, 1)."""
    return ((max(n, 1) + multiple - 1) // multiple) * multiple
