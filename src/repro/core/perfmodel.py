"""Response-time performance model (paper §8), adapted to the TPU path.

Structure follows the paper exactly:

* **Device component** (§8.1): per-invocation time ``T(i, c)`` for ``i``
  interactions against ``c`` candidates, decomposed into the three
  interaction classes — α (temporal+spatial hit), β (temporal miss),
  γ (temporal hit, spatial miss) — with per-class benchmark curves
  ``T1/T2/T3`` and invocation overhead ``Θ``::

      T(i, c) = T1(αi, c) + T2(βi, c) + T3(γi, c) − 2Θ

  On the branchless TPU path T1≈T2≈T3 per interaction (no short-circuit;
  see DESIGN.md §6) — the model keeps the 3-class split because (a) the
  benchmarks *verify* that near-equality instead of assuming it, and (b)
  α still drives the result-set transfer term.
* **α estimation** (§8.1.2): the database extent is divided into
  ``num_epochs`` epochs (paper uses 50); per epoch, sample batches of
  ``s`` consecutive query segments from a representative query set, run
  the counting kernel, and record the hit fraction.
* **β exact** (§8.1.2): for a batch, β is computed exactly from the
  temporal extremities with two binary searches per query segment
  (the paper's nested loop, vectorized): an entry overlaps iff
  ``e.ts ≤ q.te ∧ e.te ≥ q.ts``.
* **Host component** (§8.2): ``T1_host(s) = A·s^B`` fitted log-log from a
  near-zero-α benchmark (aggregate invocation overhead), and
  ``T2_host(σ) = σ / bw`` for result-set transfer of σ bytes.

The model's purpose (paper §8.3): pick a good PERIODIC batch size ``s``.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.batching import periodic
from repro.core.engine import DistanceThresholdEngine
from repro.core.segments import SegmentArray
from repro.kernels import ops

RESULT_ITEM_BYTES = 16   # entry_idx i32 + query_idx i32 + t_enter f32 + t_exit f32


# ----------------------------------------------------------------------
# synthetic single-class workloads for the T1/T2/T3 benchmarks
# ----------------------------------------------------------------------
def _make_class_tiles(c: int, q: int, cls: str, rng: np.random.Generator
                      ) -> tuple[np.ndarray, np.ndarray, float]:
    """Packed (entries, queries, d) where every interaction is class `cls`."""
    d = 1.0
    ets = rng.uniform(0.0, 1.0, c).astype(np.float32)
    qts = rng.uniform(0.0, 1.0, q).astype(np.float32)
    entries = np.zeros((c, 8), np.float32)
    queries = np.zeros((q, 8), np.float32)
    entries[:, 6], entries[:, 7] = ets, ets + 1.0
    if cls == "beta":                      # temporal miss: disjoint extents
        queries[:, 6], queries[:, 7] = qts + 10.0, qts + 11.0
    else:
        queries[:, 6], queries[:, 7] = qts * 0.0, qts * 0.0 + 2.0
    if cls == "alpha":                     # co-located ⇒ spatial hit
        entries[:, 0:6] = 0.0
        queries[:, 0:6] = 0.0
    elif cls == "gamma":                   # far apart ⇒ spatial miss
        entries[:, [0, 3]] = 0.0
        queries[:, [0, 3]] = 100.0
    return entries, queries, d


@dataclasses.dataclass
class DeviceTimeModel:
    """Interpolation tables T1/T2/T3(c, q) seconds + scalar overhead Θ."""

    c_grid: np.ndarray
    q_grid: np.ndarray
    t1: np.ndarray            # (len(c_grid), len(q_grid))
    t2: np.ndarray
    t3: np.ndarray
    theta: float              # per-invocation dispatch overhead, seconds

    def _interp(self, table: np.ndarray, c: float, q: float) -> float:
        """Bilinear interpolation in log2 space, clamped to the grid."""
        lc = np.clip(np.log2(max(c, 1.0)),
                     np.log2(self.c_grid[0]), np.log2(self.c_grid[-1]))
        lq = np.clip(np.log2(max(q, 1.0)),
                     np.log2(self.q_grid[0]), np.log2(self.q_grid[-1]))
        gc = np.log2(self.c_grid)
        gq = np.log2(self.q_grid)
        i = int(np.clip(np.searchsorted(gc, lc) - 1, 0, len(gc) - 2))
        j = int(np.clip(np.searchsorted(gq, lq) - 1, 0, len(gq) - 2))
        wc = (lc - gc[i]) / (gc[i + 1] - gc[i])
        wq = (lq - gq[j]) / (gq[j + 1] - gq[j])
        t = (table[i, j] * (1 - wc) * (1 - wq) + table[i + 1, j] * wc * (1 - wq)
             + table[i, j + 1] * (1 - wc) * wq + table[i + 1, j + 1] * wc * wq)
        return float(t)

    def predict(self, c: float, q: float, alpha: float, beta: float,
                gamma: float) -> float:
        """T(i=c·q, c) via the paper's 3-term decomposition."""
        t = (self._interp(self.t1, c, alpha * q)
             + self._interp(self.t2, c, beta * q)
             + self._interp(self.t3, c, gamma * q)
             - 2.0 * self.theta)
        return max(t, self.theta)


def benchmark_device_curves(c_values=(256, 1024, 4096, 16384),
                            q_values=(16, 64, 256, 1024),
                            *, use_pallas: bool = False, repeats: int = 3,
                            seed: int = 0) -> DeviceTimeModel:
    """Measure T1/T2/T3 on single-class synthetic workloads (paper §8.1.3)."""
    rng = np.random.default_rng(seed)
    tables = {}
    for cls_i, cls in enumerate(("alpha", "beta", "gamma")):
        tab = np.zeros((len(c_values), len(q_values)))
        for ci, c in enumerate(c_values):
            for qi, q in enumerate(q_values):
                e, qq, d = _make_class_tiles(c, q, cls, rng)
                ops.count_hits(e, qq, np.float32(d),
                               use_pallas=use_pallas).block_until_ready()  # warmup
                ts = []
                for _ in range(repeats):
                    t0 = time.perf_counter()
                    ops.count_hits(e, qq, np.float32(d),
                                   use_pallas=use_pallas).block_until_ready()
                    ts.append(time.perf_counter() - t0)
                tab[ci, qi] = float(np.median(ts))
        tables[cls] = tab
    # Θ: dispatch overhead of the smallest call.
    e, qq, d = _make_class_tiles(c_values[0], q_values[0], "beta", rng)
    ts = []
    for _ in range(max(repeats * 3, 9)):
        t0 = time.perf_counter()
        ops.count_hits(e, qq, np.float32(d),
                       use_pallas=use_pallas).block_until_ready()
        ts.append(time.perf_counter() - t0)
    theta = float(np.median(ts))
    return DeviceTimeModel(np.asarray(c_values, float), np.asarray(q_values, float),
                           tables["alpha"], tables["beta"], tables["gamma"], theta)


# ----------------------------------------------------------------------
# α estimation per epoch (paper §8.1.2)
# ----------------------------------------------------------------------
def estimate_alpha_by_epoch(engine: DistanceThresholdEngine,
                            sample_queries: SegmentArray, d: float, s: int,
                            *, num_epochs: int = 50, trials: int = 2,
                            seed: int = 0,
                            pruning: str | None = None) -> np.ndarray:
    """Per-epoch hit-fraction estimates from sampled consecutive-s batches.

    Returns (num_epochs,) float array; epochs with no sample queries reuse
    the global mean.

    ``pruning`` (default: the engine's own setting) selects the
    interaction denominator: with ``"spatial"`` the sampled batches count
    (and evaluate) only the candidate sub-ranges surviving the per-bin MBR
    pruning — the α the *pruned* workload actually exhibits (spatially
    pruned bins contribute zero hits by construction, so the numerator is
    unchanged while the denominator shrinks; pruned-workload α ≥ unpruned
    α).  Predictions fed by these α values therefore track the pruned
    interaction counts carried by the plans.
    """
    rng = np.random.default_rng(seed)
    t0, t1 = engine.db.temporal_extent
    edges = np.linspace(t0, t1, num_epochs + 1)
    q_packed = sample_queries.packed()
    qts = sample_queries.ts
    if pruning is None:
        pruning = getattr(engine, "pruning", "none")
    qlo = qhi = None
    if pruning == "spatial":
        qlo, qhi = sample_queries.mbrs()
    alphas = np.full(num_epochs, np.nan)
    for ep in range(num_epochs):
        in_ep = np.nonzero((qts >= edges[ep]) & (qts < edges[ep + 1]))[0]
        if in_ep.size == 0:
            continue
        hits = ints = 0
        for _ in range(trials):
            start = int(rng.choice(in_ep))
            start = min(start, len(sample_queries) - 1)
            stop = min(start + s, len(sample_queries))
            qt0 = float(qts[start])
            qt1 = float(sample_queries.te[start:stop].max())
            if pruning == "spatial":
                ranges = engine.index.candidate_subranges(
                    qt0, qt1, qlo[start:stop].min(axis=0),
                    qhi[start:stop].max(axis=0), float(d))
            else:
                first, last = engine.index.candidate_range(qt0, qt1)
                ranges = [(first, last)] if last >= first else []
            for first, last in ranges:
                c = last - first + 1
                n = int(ops.count_hits(engine._packed[first:last + 1],
                                       q_packed[start:stop], np.float32(d),
                                       use_pallas=False))
                hits += n
                ints += c * (stop - start)
        if ints > 0:
            alphas[ep] = hits / ints
    mean = np.nanmean(alphas) if np.isfinite(alphas).any() else 0.0
    return np.where(np.isnan(alphas), mean, alphas)


def exact_beta(engine: DistanceThresholdEngine, queries: SegmentArray,
               q_first: int, q_last: int, cand_first: int,
               cand_last: int) -> float:
    """Exact temporal-miss fraction β for one batch (paper: computable
    precisely with two nested loops; here two binary searches/query)."""
    c = cand_last - cand_first + 1
    s = q_last - q_first + 1
    if c <= 0 or s <= 0:
        return 0.0
    ets = engine.db.ts[cand_first:cand_last + 1]         # sorted
    ete_sorted = np.sort(engine.db.te[cand_first:cand_last + 1])
    qts = queries.ts[q_first:q_last + 1]
    qte = queries.te[q_first:q_last + 1]
    # overlap iff e.ts <= q.te  AND  e.te >= q.ts
    n_ts_ok = np.searchsorted(ets, qte, side="right")
    n_te_lt = np.searchsorted(ete_sorted, qts, side="left")
    overlaps = np.maximum(n_ts_ok - n_te_lt, 0)          # inclusion-exclusion
    return float(1.0 - overlaps.sum() / (c * s))


# ----------------------------------------------------------------------
# host component (paper §8.2)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class HostTimeModel:
    coef_a: float          # T1_host(s) = A * s^B  (total over all invocations)
    coef_b: float
    transfer_bw: float     # bytes/second for result marshalling

    def invocation_time(self, s: int) -> float:
        return max(self.coef_a * s ** self.coef_b, 0.0)

    def transfer_time(self, sigma_bytes: float) -> float:
        return sigma_bytes / self.transfer_bw


def benchmark_host_curves(engine: DistanceThresholdEngine,
                          queries: SegmentArray,
                          s_values=(16, 32, 64, 128, 256),
                          *, seed: int = 0) -> HostTimeModel:
    """Fit the host model from a near-zero-α run (paper: synthetic α≈0).

    We execute the engine with d≈0 (nothing within threshold ⇒ empty result
    sets) and attribute the measured host time to invocation overhead; then
    measure marshalling bandwidth with one large compaction.

    Always runs the engine's *sync* executor (``pipeline=False``): the model
    is per-invocation, and ``BatchStats.kernel_seconds`` is only measured
    per batch when every batch is individually synced (the pipelined
    executor deliberately has no per-batch timings to read).
    """
    totals = []
    for s in s_values:
        plan = periodic(engine.index, queries, s)
        _, stats = engine.execute(queries, 0.0, plan, pipeline=False)  # α ≈ 0
        _, stats = engine.execute(queries, 0.0, plan, pipeline=False)  # warm jit
        totals.append(max(stats.host_seconds, 1e-6))
    # log-log least squares: log T = log A + B log s
    ls = np.log(np.asarray(s_values, float))
    lt = np.log(np.asarray(totals))
    bmat = np.polyfit(ls, lt, 1)
    coef_b, log_a = float(bmat[0]), float(bmat[1])
    # transfer bandwidth: marshal a known-size result set
    n = 1 << 16
    arrs = [np.zeros(n, np.int32), np.zeros(n, np.int32),
            np.zeros(n, np.float32), np.zeros(n, np.float32)]
    t0 = time.perf_counter()
    _ = [np.ascontiguousarray(a) .copy() for a in arrs]
    dt = max(time.perf_counter() - t0, 1e-7)
    bw = n * RESULT_ITEM_BYTES / dt
    return HostTimeModel(float(np.exp(log_a)), coef_b, bw)


# ----------------------------------------------------------------------
# the full model
# ----------------------------------------------------------------------
@dataclasses.dataclass
class ResponseTimeModel:
    """The full §8 model — and, once :meth:`fit_alphas` has run, the one
    object feeding the whole serving stack (ROADMAP item, PR 5):
    :meth:`predict_batch_hits` is the planner's ``predict_hits`` (dispatch-
    group sizing — replaces the constant ``AUTO_GROUP_HIT_FRACTION``
    heuristic) and :meth:`predict_batch_seconds` the broker/scheduler
    ``predict_seconds`` (admission pricing, deadlines).  Both consume
    ``QueryBatch.num_ints``, which since PR 5 is the *pruned* interaction
    count — predictions track the workload actually dispatched.
    ``repro.api.TrajectoryDB.fit_response_model`` builds + attaches one."""

    device: DeviceTimeModel
    host: HostTimeModel
    num_epochs: int = 50
    #: per-epoch α fit (:meth:`fit_alphas`); None until fitted.
    alphas: np.ndarray | None = None
    #: temporal extent the α epochs divide; set by :meth:`fit_alphas`.
    extent: tuple[float, float] | None = None

    # -- per-batch predictors (planner / broker / scheduler hooks) -------
    def fit_alphas(self, engine: DistanceThresholdEngine,
                   sample_queries: SegmentArray, d: float,
                   s: int = 64, *, trials: int = 2,
                   seed: int = 0) -> "ResponseTimeModel":
        """Fit the per-epoch hit fractions against a representative
        workload (α measured over the engine's pruned candidate ranges —
        see :func:`estimate_alpha_by_epoch`) and return self."""
        self.alphas = estimate_alpha_by_epoch(
            engine, sample_queries, d, s, num_epochs=self.num_epochs,
            trials=trials, seed=seed)
        self.extent = engine.db.temporal_extent
        return self

    def _alpha_for(self, batch) -> float:
        """The fitted α of the epoch holding the batch's temporal midpoint
        (the fleet mean when the batch falls outside the fitted extent)."""
        if self.alphas is None:
            raise ValueError("call fit_alphas (or TrajectoryDB."
                             "fit_response_model) before predicting batches")
        t0, t1 = self.extent
        width = max(t1 - t0, 1e-30)
        ep = int(np.clip((0.5 * (batch.qt0 + batch.qt1) - t0) / width
                         * self.num_epochs, 0, self.num_epochs - 1))
        return float(self.alphas[ep])

    def predict_batch_hits(self, batch) -> float:
        """Predicted result rows of one ``QueryBatch``: epoch-α ×
        ``num_ints`` (pruned).  The planner's ``predict_hits`` hook."""
        return self._alpha_for(batch) * batch.num_ints

    def predict_batch_seconds(self, batch) -> float:
        """Predicted device + transfer seconds of one ``QueryBatch`` —
        the broker's admission / the scheduler's deadline unit.  β is
        taken as 0 (the batch's candidates are temporally selected, and
        the three class curves are near-equal on the branchless TPU path
        anyway — see the module docstring); the per-invocation host
        overhead is in the curves' floor ``Θ``."""
        c, q = batch.num_candidates, batch.size
        if c <= 0 or q <= 0:
            return 0.0
        a = min(max(self._alpha_for(batch), 0.0), 1.0)
        dev = self.device.predict(c, q, a, 0.0, 1.0 - a)
        return dev + self.host.transfer_time(
            a * batch.num_ints * RESULT_ITEM_BYTES)

    def predict(self, engine: DistanceThresholdEngine, queries: SegmentArray,
                d: float, s: int, alphas: np.ndarray | None = None,
                *, seed: int = 0) -> dict:
        """Predicted response time for PERIODIC with batch size s."""
        if alphas is None:
            alphas = estimate_alpha_by_epoch(engine, queries, d, s,
                                             num_epochs=self.num_epochs,
                                             seed=seed)
        t0, t1 = engine.db.temporal_extent
        width = max(t1 - t0, 1e-30)
        plan = periodic(engine.index, queries, s)
        dev = 0.0
        total_hits = 0.0
        for b in plan.batches:
            c = b.num_candidates
            if c == 0:
                continue
            ep = int(np.clip((0.5 * (b.qt0 + b.qt1) - t0) / width
                             * self.num_epochs, 0, self.num_epochs - 1))
            alpha = float(alphas[ep])
            beta = exact_beta(engine, queries, b.q_first, b.q_last,
                              b.cand_first, b.cand_last)
            gamma = max(1.0 - alpha - beta, 0.0)
            dev += self.device.predict(c, b.size, alpha, beta, gamma)
            total_hits += alpha * b.num_ints
        host = (self.host.invocation_time(s)
                + self.host.transfer_time(total_hits * RESULT_ITEM_BYTES))
        return {"s": s, "device_seconds": dev, "host_seconds": host,
                "total_seconds": dev + host,
                "predicted_hits": total_hits, "num_batches": plan.num_batches}

    def pick_batch_size(self, engine: DistanceThresholdEngine,
                        queries: SegmentArray, d: float,
                        candidates=(16, 32, 48, 64, 96, 128, 192, 256),
                        *, seed: int = 0) -> tuple[int, list[dict]]:
        """Model-driven batch-size selection (the paper's Table 3 use)."""
        preds = [self.predict(engine, queries, d, s, seed=seed)
                 for s in candidates]
        best = min(preds, key=lambda p: p["total_seconds"])
        return int(best["s"]), preds
