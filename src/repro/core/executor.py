"""Execution layer: dispatcher protocol + the sync / pipelined executors.

The counterpart of ``repro.core.planner``: a :class:`~repro.core.planner.
QueryPlan` says *what* to run; this module runs it.  The PR 2 two-phase
pipelined dispatch (phase A: async-dispatch every batch with no host reads;
phase B: one ``block_until_ready``, exact counts, re-dispatch only
overflowed batches, sync once more) is generalized into an executor that
drives any :class:`BatchDispatcher` — the seam that lets the single-device
engine (``repro.core.engine``) and the sharded mesh backend
(``repro.core.distributed.ShardedEngine``) share the ≤ 2-host-syncs-per-
query-set property instead of each reimplementing the loop.

A dispatcher answers four questions, all device-strategy-specific:

* ``dispatch(batch, capacity)`` — queue the batch's device computation
  asynchronously (slicing, padding, sharding — whatever the strategy
  needs) and return a :class:`Dispatch` handle whose ``out`` is blockable.
* ``count(dp)`` — the exact global hit count, read *after* a sync (for the
  sharded dispatcher this is the ``psum``-reduced total).
* ``retry_capacity(dp)`` — ``None`` if the dispatch's buffers held every
  hit, else the (bucketed, ≥ doubled) capacity a re-dispatch needs.  The
  kernels always report exact counts, so one retry always converges.
* ``marshal(dp, count)`` — host-side assembly of the device buffers into a
  ``ResultSet`` part.

Two executors drive a dispatcher over a plan:

* :class:`SyncExecutor` — the classic per-batch loop (dispatch → sync →
  maybe retry → marshal).  One host sync per invocation; per-batch device
  timings are observable, which the §8 perf-model fits need.
* :class:`PipelinedExecutor` — the two-phase dispatch, *per dispatch
  group*: group k+1 is dispatched before group k is synced and marshalled,
  so host-side result assembly of group k overlaps device compute of group
  k+1.  With the default single-group plan this is exactly PR 2's O(1)-sync
  executor (``ExecStats.num_syncs ≤ 2``); with G groups it is ≤ 2·G syncs
  and marshalling never leaves the device idle between groups.

``ResultSet`` / ``BatchStats`` / ``ExecStats`` moved here from
``repro.core.engine`` (which re-exports them — import paths are stable).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Callable, Protocol, runtime_checkable

import jax
import numpy as np

from repro.core.batching import QueryBatch
from repro.core.errors import CapacityError
from repro.core.planner import QueryPlan


# ----------------------------------------------------------------------
# Dispatch-group attribution (lint/sentinel seam).
# ----------------------------------------------------------------------
#: Per-thread label of the dispatch group currently executing — published
#: by both executors so observability layers (``repro.lint.sentinel``'s
#: blocking-read attribution) can blame a device→host stall on the group
#: that performed it without the executors knowing the sentinel exists.
#: Thread-local because the deadline scheduler runs whole groups on pool
#: threads concurrently.
_dispatch_context = threading.local()


def current_group_label() -> str | None:
    """The calling thread's active dispatch-group label (e.g.
    ``"pipelined:finish:3"``), or ``None`` outside any group scope."""
    return getattr(_dispatch_context, "label", None)


@contextlib.contextmanager
def _group_scope(label: str):
    prev = getattr(_dispatch_context, "label", None)
    _dispatch_context.label = label
    try:
        yield
    finally:
        _dispatch_context.label = prev


# ----------------------------------------------------------------------
# Results + stats (moved from repro.core.engine; engine re-exports).
# ----------------------------------------------------------------------
@dataclasses.dataclass
class ResultSet:
    """Flat result arrays: one row per (entry segment, query segment, interval)."""

    entry_idx: np.ndarray    # global index into the sorted database
    entry_traj: np.ndarray   # trajectory id of the entry segment
    entry_seg: np.ndarray    # segment id of the entry segment
    query_idx: np.ndarray    # global index into the sorted query array
    t_enter: np.ndarray
    t_exit: np.ndarray

    def __len__(self) -> int:
        return int(self.entry_idx.shape[0])

    @staticmethod
    def empty() -> "ResultSet":
        zi = np.zeros(0, np.int64)
        zf = np.zeros(0, np.float32)
        return ResultSet(zi, zi.copy(), zi.copy(), zi.copy(), zf, zf.copy())

    @staticmethod
    def concatenate(parts: list["ResultSet"]) -> "ResultSet":
        if not parts:
            return ResultSet.empty()
        return ResultSet(*[np.concatenate([getattr(p, f.name) for p in parts])
                           for f in dataclasses.fields(ResultSet)])

    def sorted_canonical(self) -> "ResultSet":
        """Canonical (entry_idx, query_idx) order — for set comparisons."""
        order = np.lexsort((self.query_idx, self.entry_idx))
        return ResultSet(*[getattr(self, f.name)[order]
                           for f in dataclasses.fields(ResultSet)])


@dataclasses.dataclass
class BatchStats:
    """Per-invocation record (feeds the §8 performance model).

    ``kernel_seconds`` is dispatch + device time of the batch's first
    invocation (timed with ``block_until_ready``); ``retry_seconds`` is the
    wall time of overflow re-dispatches, kept separate so perf-model fits
    see clean per-invocation numbers.  Pipelined execution reports both as
    zero per batch (see ``ExecStats.sync_seconds``).
    """

    batch_size: int
    num_candidates: int
    num_interactions: int
    num_hits: int
    kernel_seconds: float
    retries: int
    retry_seconds: float = 0.0
    #: kernel grid tiles the in-kernel spatial early-out skipped / total
    #: (PR 5; zero on paths without a tile loop — dense compaction, jnp).
    pruned_tiles: int = 0
    num_tiles: int = 0


@dataclasses.dataclass
class ExecStats:
    plan_seconds: float
    total_seconds: float
    batches: list[BatchStats]
    #: host↔device synchronization points (count reads / block_until_ready):
    #: one per invocation (+retries) in sync mode; ≤ 2 per dispatch group in
    #: pipelined mode — ≤ 2 per query set with the default single group.
    num_syncs: int = 0
    #: pipelined mode only: wall time of the phase A async dispatches and of
    #: the phase B device waits (summed over dispatch groups).
    dispatch_seconds: float = 0.0
    sync_seconds: float = 0.0
    pipelined: bool = False
    #: dispatch groups the executor processed (1 = classic whole-plan phase).
    num_groups: int = 1
    #: interactions the *planner's* spatial pruning removed before dispatch
    #: (candidate sub-range trimming — ``QueryPlan.pruned_interactions``);
    #: the in-kernel tile early-out is accounted per batch in
    #: ``BatchStats.pruned_tiles`` / :attr:`pruned_tiles`.
    pruned_interactions: int = 0
    #: degradation-ladder steps taken while producing this result (PR 10):
    #: populated by the serving broker when repeated failures forced a
    #: compaction / backend / pruning / route downgrade.  Empty on every
    #: clean execution.
    degradations: list = dataclasses.field(default_factory=list)

    @property
    def pruned_tiles(self) -> int:
        return sum(b.pruned_tiles for b in self.batches)

    @property
    def total_tiles(self) -> int:
        return sum(b.num_tiles for b in self.batches)

    @property
    def kernel_seconds(self) -> float:
        """First-dispatch device time (+ the pipelined device wait) — retry
        re-dispatch time is deliberately excluded so perf-model fits see
        per-invocation numbers; it is accounted in :attr:`retry_seconds`."""
        return sum(b.kernel_seconds for b in self.batches) + self.sync_seconds

    @property
    def retry_seconds(self) -> float:
        return sum(b.retry_seconds for b in self.batches)

    @property
    def host_seconds(self) -> float:
        """Wall time not spent on device work: retries are device time too,
        so they are subtracted alongside kernel_seconds."""
        return self.total_seconds - self.kernel_seconds - self.retry_seconds

    @property
    def total_interactions(self) -> int:
        return sum(b.num_interactions for b in self.batches)

    @property
    def total_hits(self) -> int:
        return sum(b.num_hits for b in self.batches)

    @property
    def num_invocations(self) -> int:
        return len(self.batches)

    @property
    def total_retries(self) -> int:
        return sum(b.retries for b in self.batches)


# ----------------------------------------------------------------------
# Dispatcher protocol.
# ----------------------------------------------------------------------
@dataclasses.dataclass
class Dispatch:
    """One in-flight batch dispatch: the batch, its result capacity, the
    blockable device outputs, and optional dispatcher-private context
    (e.g. the sharded dispatcher's per-pod layout)."""

    batch: QueryBatch
    capacity: int
    out: object
    ctx: object = None


@runtime_checkable
class BatchDispatcher(Protocol):
    """One device-execution strategy, bound to a query set + threshold.

    ``dispatch`` must be asynchronous (no host reads); ``count`` /
    ``retry_capacity`` / ``marshal`` are only called after the executor has
    blocked on ``Dispatch.out``.

    A dispatcher may additionally expose ``redispatch(dp, capacity)`` — an
    overflow re-dispatch of the same batch at a larger capacity that can
    reuse ``dp.ctx`` (prepared host inputs) instead of rebuilding them;
    executors fall back to ``dispatch(dp.batch, capacity)`` when absent.
    """

    def dispatch(self, batch: QueryBatch, capacity: int) -> Dispatch: ...

    def count(self, dp: Dispatch) -> int: ...

    def retry_capacity(self, dp: Dispatch) -> int | None: ...

    def marshal(self, dp: Dispatch, count: int) -> ResultSet | None: ...


def _redispatch(dispatcher: BatchDispatcher, dp: Dispatch,
                capacity: int) -> Dispatch:
    """Overflow re-dispatch, reusing prepared inputs when the dispatcher
    supports it."""
    redo = getattr(dispatcher, "redispatch", None)
    if redo is not None:
        return redo(dp, capacity)
    return dispatcher.dispatch(dp.batch, capacity)


def _tile_stats(dispatcher: BatchDispatcher, dp: Dispatch) -> tuple[int, int]:
    """(pruned_tiles, num_tiles) of a synced dispatch — an *optional*
    dispatcher hook (kernel-level spatial pruning accounting); dispatchers
    without it report zeros.  Only called after the executor has blocked on
    ``dp.out``, so reading the counters costs no extra host sync."""
    fn = getattr(dispatcher, "tile_stats", None)
    return fn(dp) if fn is not None else (0, 0)


def _record_empty(dispatcher: BatchDispatcher, batch: QueryBatch) -> None:
    """Tell the dispatcher a zero-candidate batch was skipped host-side —
    an *optional* hook (routing/accounting ledgers need an explicit
    empty record per planned batch, not a silent gap); dispatchers
    without it see nothing."""
    fn = getattr(dispatcher, "record_empty", None)
    if fn is not None:
        fn(batch)


def _empty_stats(batch: QueryBatch) -> BatchStats:
    return BatchStats(batch.size, 0, 0, 0, 0.0, 0)


#: Group-completion hook ``(group_index, batch_indices, group_results)`` —
#: fired by both executors as soon as one dispatch group's results are
#: marshalled (for the pipelined executor that is while the *next* group is
#: still computing).  The incremental-delivery seam for streaming
#: consumers: ``DeadlineScheduler.execute(on_group=...)`` exposes it with
#: first-completion deduplication.  (The serving broker delivers slices by
#: running one single-group sub-plan per pump step instead — see
#: ``repro.serve.broker``.)
GroupHook = Callable[[int, "list[int]", ResultSet], None]


# ----------------------------------------------------------------------
# Executors.
# ----------------------------------------------------------------------
class SyncExecutor:
    """Classic per-batch loop: dispatch → sync → (maybe retry) → next.

    Used for §8 perf-model fits, which need per-invocation device timings —
    the pipelined executor deliberately makes those unobservable.
    """

    pipelined = False

    def __init__(self, dispatcher: BatchDispatcher, *,
                 on_group: GroupHook | None = None,
                 max_capacity_retries: int = 3):
        self.dispatcher = dispatcher
        self.on_group = on_group
        self.max_capacity_retries = int(max_capacity_retries)

    def run(self, plan: QueryPlan) -> tuple[ResultSet, ExecStats]:
        t_begin = time.perf_counter()
        disp = self.dispatcher
        nb = plan.num_batches
        groups = plan.groups if plan.groups else (
            [list(range(nb))] if nb else [])
        parts: list[ResultSet] = []
        stats_by_idx: dict[int, BatchStats] = {}
        num_syncs = 0
        for gi, g in enumerate(groups):
            group_parts: list[ResultSet] = []
            with _group_scope(f"sync:{gi}"):
                for i in g:
                    batch, capacity = plan.batches[i], plan.capacities[i]
                    if batch.num_candidates == 0:
                        _record_empty(disp, batch)
                        stats_by_idx[i] = _empty_stats(batch)
                        continue
                    t0 = time.perf_counter()
                    dp = disp.dispatch(batch, capacity)
                    jax.block_until_ready(dp.out)
                    kernel_s = time.perf_counter() - t0
                    num_syncs += 1
                    count = disp.count(dp)
                    retries = 0
                    retry_s = 0.0
                    while (cap2 := disp.retry_capacity(dp)) is not None:
                        if retries >= self.max_capacity_retries:
                            raise CapacityError(
                                count, dp.capacity, batch_index=i,
                                retries=retries)
                        t0r = time.perf_counter()
                        dp = _redispatch(disp, dp, cap2)
                        jax.block_until_ready(dp.out)
                        retry_s += time.perf_counter() - t0r
                        num_syncs += 1
                        count = disp.count(dp)
                        retries += 1
                    part = disp.marshal(dp, count)
                    if part is not None:
                        group_parts.append(part)
                    pt, nt = _tile_stats(disp, dp)
                    stats_by_idx[i] = BatchStats(
                        batch.size, batch.num_candidates,
                        batch.size * batch.num_candidates, count,
                        kernel_s, retries, retry_s,
                        pruned_tiles=pt, num_tiles=nt)
            parts.extend(group_parts)
            if self.on_group is not None:
                self.on_group(gi, list(g), ResultSet.concatenate(group_parts))
        total = time.perf_counter() - t_begin
        stats = [stats_by_idx[i] for i in range(nb)]
        return (ResultSet.concatenate(parts),
                ExecStats(plan.plan_seconds, total, stats,
                          num_syncs=num_syncs, pipelined=False,
                          num_groups=max(plan.num_groups, 1),
                          pruned_interactions=getattr(
                              plan, "pruned_interactions", 0)))


class PipelinedExecutor:
    """Two-phase group-wise executor: dispatch everything in a group, sync
    once, retry only overflows, and marshal while the *next* group computes.

    Per group: phase A queues every batch's device computation via JAX
    async dispatch (no host reads, so the host never stalls between
    batches); phase B performs one ``block_until_ready`` over the group,
    reads every exact count, re-dispatches only the overflowed batches at
    enlarged (≥ doubled, bucketed) capacity, and syncs those once more —
    ≤ 2 host syncs per group, ≤ 2 per query set with the default
    single-group plan.  Group k's phase B (including host-side result
    marshalling) runs *after* group k+1's phase A, so assembly of group k
    overlaps device compute of group k+1.
    """

    pipelined = True

    def __init__(self, dispatcher: BatchDispatcher, *,
                 on_group: GroupHook | None = None,
                 max_capacity_retries: int = 3):
        self.dispatcher = dispatcher
        self.on_group = on_group
        self.max_capacity_retries = int(max_capacity_retries)

    def run(self, plan: QueryPlan) -> tuple[ResultSet, ExecStats]:
        t_begin = time.perf_counter()
        disp = self.dispatcher
        nb = plan.num_batches
        groups = plan.groups if plan.groups else (
            [list(range(nb))] if nb else [])
        slots: dict[int, Dispatch] = {}
        counts: dict[int, int] = {}
        retried: dict[int, float] = {}     # batch idx -> retry wall share
        rounds: dict[int, int] = {}        # batch idx -> overflow retries
        parts: dict[int, ResultSet] = {}
        timing = {"dispatch": 0.0, "sync": 0.0, "syncs": 0}

        def dispatch_group(gi: int, g: list[int]) -> None:
            t0 = time.perf_counter()
            with _group_scope(f"pipelined:dispatch:{gi}"):
                for i in g:
                    batch = plan.batches[i]
                    if batch.num_candidates == 0:
                        _record_empty(disp, batch)
                        continue
                    slots[i] = disp.dispatch(batch, plan.capacities[i])
            timing["dispatch"] += time.perf_counter() - t0

        def finish_group(gi: int, g: list[int]) -> None:
            live = [i for i in g if i in slots]
            if not live:
                if self.on_group is not None:
                    self.on_group(gi, list(g), ResultSet.empty())
                return
            with _group_scope(f"pipelined:finish:{gi}"):
                t0 = time.perf_counter()
                jax.block_until_ready([slots[i].out for i in live])
                timing["syncs"] += 1
                for i in live:
                    counts[i] = disp.count(slots[i])
                # Re-dispatch only overflowed batches; exact counts make one
                # retry sufficient on honest devices, so the bound below only
                # bites when counts are corrupted or capacities adversarial.
                t_retry = time.perf_counter()
                any_redo = False
                while True:
                    redo = []
                    for i in live:
                        cap2 = disp.retry_capacity(slots[i])
                        if cap2 is None:
                            continue
                        if rounds.get(i, 0) >= self.max_capacity_retries:
                            raise CapacityError(
                                counts[i], slots[i].capacity, batch_index=i,
                                retries=rounds.get(i, 0))
                        rounds[i] = rounds.get(i, 0) + 1
                        slots[i] = _redispatch(disp, slots[i], cap2)
                        redo.append(i)
                    if not redo:
                        break
                    any_redo = True
                    jax.block_until_ready([slots[i].out for i in redo])
                    timing["syncs"] += 1
                    for i in redo:
                        counts[i] = disp.count(slots[i])
                retry_s = time.perf_counter() - t_retry if any_redo else 0.0
                timing["sync"] += (time.perf_counter() - t0) - retry_s
                grp_redo = [i for i in live if rounds.get(i, 0)]
                for i in grp_redo:
                    retried[i] = retry_s / len(grp_redo)
                # Host-side marshalling — by now the next group's phase A
                # has already queued its device work, so this overlaps
                # compute.
                for i in live:
                    part = disp.marshal(slots[i], counts[i])
                    if part is not None:
                        parts[i] = part
            if self.on_group is not None:
                self.on_group(gi, list(g), ResultSet.concatenate(
                    [parts[i] for i in g if i in parts]))

        for gi, g in enumerate(groups):
            dispatch_group(gi, g)
            if gi > 0:
                finish_group(gi - 1, groups[gi - 1])
        if groups:
            finish_group(len(groups) - 1, groups[-1])

        stats = []
        for i, batch in enumerate(plan.batches):
            if batch.num_candidates == 0:
                stats.append(_empty_stats(batch))
                continue
            pt, nt = (_tile_stats(disp, slots[i]) if i in slots else (0, 0))
            stats.append(BatchStats(
                batch.size, batch.num_candidates,
                batch.size * batch.num_candidates, counts.get(i, 0), 0.0,
                rounds.get(i, 0), retried.get(i, 0.0),
                pruned_tiles=pt, num_tiles=nt))
        total = time.perf_counter() - t_begin
        ordered = [parts[i] for i in sorted(parts)]
        return (ResultSet.concatenate(ordered),
                ExecStats(plan.plan_seconds, total, stats,
                          num_syncs=timing["syncs"],
                          dispatch_seconds=timing["dispatch"],
                          sync_seconds=timing["sync"], pipelined=True,
                          num_groups=max(len(groups), 1),
                          pruned_interactions=getattr(
                              plan, "pruned_interactions", 0)))


def make_executor(dispatcher: BatchDispatcher, *, pipeline: bool,
                  on_group: GroupHook | None = None,
                  max_capacity_retries: int = 3):
    """The executor for ``pipeline=True`` (two-phase, O(1) syncs per group)
    or ``pipeline=False`` (per-batch sync loop with observable timings).
    ``on_group`` fires as each dispatch group's results are marshalled.
    ``max_capacity_retries`` bounds overflow re-dispatches per batch;
    exceeding it raises :class:`~repro.core.errors.CapacityError`."""
    cls = PipelinedExecutor if pipeline else SyncExecutor
    return cls(dispatcher, on_group=on_group,
               max_capacity_retries=max_capacity_retries)


__all__ = [
    "BatchDispatcher", "BatchStats", "Dispatch", "ExecStats", "GroupHook",
    "PipelinedExecutor", "ResultSet", "SyncExecutor", "make_executor",
]
