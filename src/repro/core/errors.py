"""Structured failure types for the serving stack (PR 10).

Permanent failures must be *diagnosable*: a capacity overflow that keeps
overflowing after its bounded retries, or a pod that drops out of the
shard mesh, surfaces as one of these exceptions instead of an anonymous
``RuntimeError`` (or, worse, an unbounded retry loop).  Both carry the
exact numbers a caller needs to re-submit with a corrected policy.
"""
from __future__ import annotations


class CapacityError(RuntimeError):
    """A batch kept overflowing its result buffer after the bounded
    capacity-doubling retries (``ExecutionPolicy.max_capacity_retries``).

    Attributes carry the exact observed hit count and the capacity it
    exceeded, so the caller can re-submit with
    ``policy.with_(capacity=...)`` sized from ``count``.
    """

    def __init__(self, count: int, capacity: int, *,
                 batch_index: int | None = None, retries: int = 0):
        self.count = int(count)
        self.capacity = int(capacity)
        self.batch_index = batch_index
        self.retries = int(retries)
        where = f" (batch {batch_index})" if batch_index is not None else ""
        super().__init__(
            f"result buffer overflow{where}: {self.count} hits exceed "
            f"capacity {self.capacity} after {self.retries} bounded "
            f"retries; re-submit with capacity >= {self.count} or raise "
            f"max_capacity_retries")


class PodFailedError(RuntimeError):
    """A temporal pod of the shard mesh failed to execute its slice.

    The broker's degradation ladder catches this and re-routes the
    group's batches to the single-device engine (results stay
    byte-identical — degraded, never wrong); outside the broker it
    propagates so callers see a structured error rather than a hang.
    """

    def __init__(self, pod: int | None = None, reason: str = "pod failure"):
        self.pod = pod
        where = f"pod {pod}" if pod is not None else "pod"
        super().__init__(f"{where} dropped out of the shard mesh: {reason}")


__all__ = ["CapacityError", "PodFailedError"]
